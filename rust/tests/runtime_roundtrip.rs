//! Integration: AOT artifacts executed via PJRT must agree with the
//! pure-rust oracles — the cross-language correctness contract.
//!
//! Requires `make artifacts`; every test skips gracefully when the
//! artifacts are absent so `cargo test` still passes pre-build.

use std::path::Path;

use bspmm::gcn::{params::ParamSet, reference};
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::runtime::{Runtime, Tensor};
use bspmm::sparse::batch::{densify_batch, random_dense_batch, PaddedCsrBatch, PaddedStBatch};
use bspmm::sparse::ops;
use bspmm::sparse::random::{random_batch, RandomSpec};
use bspmm::sparse::Dense;
use bspmm::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime init"))
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol + tol * w.abs(),
            "{what}: index {i}: got {g}, want {w}"
        );
    }
}

#[test]
fn spmm_st_artifact_matches_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(42);
    let sw = rt.manifest.sweep("fig8a").unwrap();
    let nb = sw.nbs[0];
    let mats = random_batch(&mut rng, &RandomSpec::new(sw.dim, sw.z), sw.batch);
    let st = PaddedStBatch::pack(&mats, sw.dim, sw.nnz_cap()).unwrap();
    let dense = random_dense_batch(&mut rng, sw.batch, sw.dim, nb);

    let out = rt
        .run(
            &sw.st_batched(nb),
            &[
                Tensor::i32(&[sw.batch, sw.nnz_cap(), 2], st.ids.clone()),
                Tensor::f32(&[sw.batch, sw.nnz_cap()], st.vals.clone()),
                Tensor::f32(&[sw.batch, sw.dim, nb], dense.clone()),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();

    for (bi, m) in mats.iter().enumerate() {
        let b = Dense {
            rows: sw.dim,
            cols: nb,
            data: dense[bi * sw.dim * nb..(bi + 1) * sw.dim * nb].to_vec(),
        };
        let expect = ops::spmm_st(&m.to_sparse_tensor(), &b);
        assert_close(
            &got[bi * sw.dim * nb..(bi + 1) * sw.dim * nb],
            &expect.data,
            1e-4,
            &format!("st batch {bi}"),
        );
    }
}

#[test]
fn spmm_csr_artifact_matches_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(43);
    let sw = rt.manifest.sweep("fig9e").unwrap();
    let nb = *sw.nbs.last().unwrap();
    let mats = random_batch(&mut rng, &RandomSpec::new(sw.dim, sw.z), sw.batch);
    let csr = PaddedCsrBatch::pack(&mats, sw.dim, sw.nnz_cap()).unwrap();
    let dense = random_dense_batch(&mut rng, sw.batch, sw.dim, nb);

    let out = rt
        .run(
            &sw.csr_batched(nb),
            &[
                Tensor::i32(&[sw.batch, sw.dim + 1], csr.rpt.clone()),
                Tensor::i32(&[sw.batch, sw.nnz_cap()], csr.col_ids.clone()),
                Tensor::f32(&[sw.batch, sw.nnz_cap()], csr.vals.clone()),
                Tensor::f32(&[sw.batch, sw.dim, nb], dense.clone()),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();

    for (bi, m) in mats.iter().enumerate() {
        let b = Dense {
            rows: sw.dim,
            cols: nb,
            data: dense[bi * sw.dim * nb..(bi + 1) * sw.dim * nb].to_vec(),
        };
        let expect = ops::spmm_csr(&m.to_csr(), &b);
        assert_close(
            &got[bi * sw.dim * nb..(bi + 1) * sw.dim * nb],
            &expect.data,
            1e-4,
            &format!("csr batch {bi}"),
        );
    }
}

#[test]
fn gemm_artifact_matches_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(44);
    let sw = rt.manifest.sweep("fig8a").unwrap();
    let nb = sw.nbs[1];
    let mats = random_batch(&mut rng, &RandomSpec::new(sw.dim, sw.z), sw.batch);
    let a = densify_batch(&mats, sw.dim);
    let dense = random_dense_batch(&mut rng, sw.batch, sw.dim, nb);

    let out = rt
        .run(
            &sw.gemm_batched(nb),
            &[
                Tensor::f32(&[sw.batch, sw.dim, sw.dim], a),
                Tensor::f32(&[sw.batch, sw.dim, nb], dense.clone()),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    for (bi, m) in mats.iter().enumerate() {
        let b = Dense {
            rows: sw.dim,
            cols: nb,
            data: dense[bi * sw.dim * nb..(bi + 1) * sw.dim * nb].to_vec(),
        };
        let expect = ops::gemm(&m.to_dense(), &b);
        assert_close(
            &got[bi * sw.dim * nb..(bi + 1) * sw.dim * nb],
            &expect.data,
            1e-3,
            &format!("gemm batch {bi}"),
        );
    }
}

#[test]
fn single_artifacts_match_batched_slices() {
    // The non-batched dispatch path must produce the same numbers as the
    // batched one — the semantics-preservation claim of §IV-C.
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(45);
    let sw = rt.manifest.sweep("fig8a").unwrap();
    let nb = sw.nbs[0];
    let mats = random_batch(&mut rng, &RandomSpec::new(sw.dim, sw.z), 4);
    let st = PaddedStBatch::pack(&mats, sw.dim, sw.nnz_cap()).unwrap();
    let dense = random_dense_batch(&mut rng, 4, sw.dim, nb);

    for bi in 0..4 {
        let one = st.single(bi);
        let out = rt
            .run(
                &sw.st_single(nb),
                &[
                    Tensor::i32(&[1, sw.nnz_cap(), 2], one.ids.clone()),
                    Tensor::f32(&[1, sw.nnz_cap()], one.vals.clone()),
                    Tensor::f32(
                        &[1, sw.dim, nb],
                        dense[bi * sw.dim * nb..(bi + 1) * sw.dim * nb].to_vec(),
                    ),
                ],
            )
            .unwrap();
        let got = out[0].as_f32().unwrap();
        let b = Dense {
            rows: sw.dim,
            cols: nb,
            data: dense[bi * sw.dim * nb..(bi + 1) * sw.dim * nb].to_vec(),
        };
        let expect = ops::spmm_st(&mats[bi].to_sparse_tensor(), &b);
        assert_close(got, &expect.data, 1e-4, &format!("single {bi}"));
    }
}

#[test]
fn model_fwd_artifact_matches_rust_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = rt.manifest.model("tox21").unwrap().clone();
    let ps = ParamSet::load_init(&cfg, &rt.manifest.dir).unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, cfg.train_batch, 7);
    let idx: Vec<usize> = (0..cfg.train_batch).collect();
    let mb = data.pack_batch(&idx, cfg.max_nodes, cfg.ell_width).unwrap();

    let mut inputs: Vec<Tensor> = Vec::new();
    for (p, view) in cfg.params.iter().zip(ps.views(&cfg)) {
        inputs.push(Tensor::f32(&p.shape, view.to_vec()));
    }
    inputs.push(Tensor::i32(
        &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
        mb.ell_cols.clone(),
    ));
    inputs.push(Tensor::f32(
        &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
        mb.ell_vals.clone(),
    ));
    inputs.push(Tensor::f32(&[mb.batch, mb.max_nodes, mb.feat_dim], mb.x.clone()));
    inputs.push(Tensor::f32(&[mb.batch, mb.max_nodes], mb.mask.clone()));

    let out = rt.run(&cfg.artifact_fwd_train, &inputs).unwrap();
    let got = out[0].as_f32().unwrap();
    let want = reference::forward(&cfg, &ps, &mb).unwrap();
    assert_close(got, &want, 2e-3, "tox21 logits");
}

#[test]
fn executable_rejects_abi_mismatch() {
    // Shape/dtype/arity drift between the manifest and caller must fail
    // loudly, not produce garbage.
    let Some(rt) = runtime_or_skip() else { return };
    let sw = rt.manifest.sweep("fig8a").unwrap();
    let nb = sw.nbs[0];
    let exe = rt.executable(&sw.st_single(nb)).unwrap();
    // wrong arity
    assert!(exe.execute(&[]).is_err());
    // wrong shape
    let bad = vec![
        Tensor::i32(&[1, sw.nnz_cap(), 2], vec![0; sw.nnz_cap() * 2]),
        Tensor::f32(&[1, sw.nnz_cap()], vec![0.0; sw.nnz_cap()]),
        Tensor::f32(&[1, sw.dim, nb + 1], vec![0.0; sw.dim * (nb + 1)]),
    ];
    assert!(exe.execute(&bad).is_err());
    // wrong dtype (ids as f32)
    let bad = vec![
        Tensor::f32(&[1, sw.nnz_cap(), 2], vec![0.0; sw.nnz_cap() * 2]),
        Tensor::f32(&[1, sw.nnz_cap()], vec![0.0; sw.nnz_cap()]),
        Tensor::f32(&[1, sw.dim, nb], vec![0.0; sw.dim * nb]),
    ];
    assert!(exe.execute(&bad).is_err());
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.executable("no_such_artifact").is_err());
}

#[test]
fn perf_ablation_variants_agree_numerically() {
    // loop / vec / fused formulations of the same kernel must produce
    // identical numbers (the §Perf iterations are perf-only changes).
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(77);
    let (dim, z, nb, batch) = (50usize, 2usize, 64usize, 50usize);
    let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
    let st = PaddedStBatch::pack(&mats, dim, dim * z).unwrap();
    let dense = random_dense_batch(&mut rng, batch, dim, nb);
    let inputs = vec![
        Tensor::i32(&[batch, dim * z, 2], st.ids.clone()),
        Tensor::f32(&[batch, dim * z], st.vals.clone()),
        Tensor::f32(&[batch, dim, nb], dense.clone()),
    ];
    let fused = rt
        .run(&format!("spmm_st_d{dim}_z{z}_n{nb}_b{batch}"), &inputs)
        .unwrap();
    for variant in ["loop", "vec"] {
        let out = rt
            .run(
                &format!("spmm_st_{variant}_d{dim}_z{z}_n{nb}_b{batch}"),
                &inputs,
            )
            .unwrap();
        assert_close(
            out[0].as_f32().unwrap(),
            fused[0].as_f32().unwrap(),
            1e-4,
            &format!("variant {variant} vs fused"),
        );
    }
}
