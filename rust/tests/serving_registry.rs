//! Multi-model serving under concurrent parameter hot swap — the
//! DESIGN.md §15 acceptance suite.
//!
//! The contract pinned here:
//!
//! * **No mixed-version batch.** Every response carries the `version`
//!   its logits were computed under and the `batch_seq` of the engine
//!   dispatch it rode in; all responses sharing a `batch_seq` must
//!   share a `version`, even while a writer thread hammers
//!   [`ModelRegistry::swap_params`] under traffic.
//! * **Bit-identical replay.** Each served logit vector equals, bit for
//!   bit, a direct offline replay of the same packed batch on exactly
//!   the registered parameter version the response was stamped with.
//! * **Warm multi-model steady state.** With every model's plan
//!   artifacts exported and warm-started, a mixed-model run serves
//!   with `plans_built == 0`, and the plan arena stays within the
//!   global budget.
//! * **Unknown models are shed**, never executed.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::{CloseRule, ModelRegistry, MultiDispatcher};
use bspmm::gcn::ParamSet;
use bspmm::graph::dataset::pack_molecules;
use bspmm::graph::molecule::{Molecule, MoleculeSpec};
use bspmm::util::rng::Rng;

const MODELS: [&str; 2] = ["tox21", "reaction100"];
const MAX_BATCH: usize = 8;

fn two_model_registry() -> Arc<ModelRegistry> {
    let mut reg = ModelRegistry::new();
    for m in MODELS {
        reg.register_synthetic(m, 0x5EED).unwrap();
    }
    Arc::new(reg)
}

/// Compile each model's full-capacity serve plan offline and export the
/// per-model artifact layout (`root/<model>/`) the server warm-starts
/// from.
fn export_warm_plans(registry: &Arc<ModelRegistry>, root: &PathBuf) {
    let mut md = MultiDispatcher::new(Arc::clone(registry), 1);
    let mut rng = Rng::new(0xCA11);
    let spec = MoleculeSpec::default();
    for m in MODELS {
        let cfg = registry.cfg(m).unwrap().clone();
        let mols: Vec<Molecule> = (0..MAX_BATCH)
            .map(|_| Molecule::random(&mut rng, &spec))
            .collect();
        let refs: Vec<&Molecule> = mols.iter().collect();
        let mb =
            pack_molecules(&refs, MAX_BATCH, cfg.max_nodes, cfg.ell_width, cfg.n_out).unwrap();
        md.forward(m, DispatchMode::Batched, &mb).unwrap();
    }
    let exported = md.export_plans(root).unwrap();
    assert!(exported >= MODELS.len(), "exported {exported} plan artifacts");
}

fn multi_model_server(registry: &Arc<ModelRegistry>, plans_dir: Option<PathBuf>) -> Server {
    Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("unused-for-host-backend"),
        model: "tox21".into(),
        mode: DispatchMode::Batched,
        backend: ServeBackend::HostEngine { threads: 2 },
        max_batch: MAX_BATCH,
        max_wait: Duration::from_millis(2),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: None,
        registry: Some(Arc::clone(registry)),
        plans_dir,
    })
    .expect("multi-model server start")
}

#[test]
fn concurrent_hot_swap_never_mixes_versions_and_replays_bit_identically() {
    let registry = two_model_registry();
    let plans_root =
        std::env::temp_dir().join(format!("bspmm-hot-swap-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&plans_root);
    export_warm_plans(&registry, &plans_root);

    let srv = multi_model_server(&registry, Some(plans_root.clone()));

    // A writer thread hammers tox21 swaps for the whole run — the
    // concurrency stress. `swap_params` only ever installs a complete
    // new Arc, so the server must keep answering on *some* registered
    // version, one per batch.
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let registry = Arc::clone(&registry);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let cfg = registry.cfg("tox21").unwrap().clone();
            let mut seed = 0xBEEF_u64;
            while !stop.load(Ordering::Relaxed) {
                registry
                    .swap_params("tox21", ParamSet::random_init(&cfg, seed))
                    .unwrap();
                seed += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    // Two submission phases with a deterministic swap between them:
    // phase-0 responses are all served before the swap installs, so
    // phase-1 tox21 responses must carry a strictly newer version —
    // at least two distinct versions serve even if the writer thread
    // is starved.
    let mut rng = Rng::new(0x51AB);
    let spec = MoleculeSpec::default();
    let mut by_id: BTreeMap<u64, Molecule> = BTreeMap::new();
    let mut responses = Vec::new();
    let mut phase0_max_tox21_version = 0u64;
    for phase in 0..2 {
        let rxs: Vec<_> = (0..60)
            .map(|i| {
                let model = MODELS[i % MODELS.len()];
                let mol = Molecule::random(&mut rng, &spec);
                (mol.clone(), srv.submit_to(model, mol))
            })
            .collect();
        for (mol, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
            assert!(!resp.shed, "unexpected shed for request {}", resp.id);
            assert!(resp.version >= 1, "served without a registry version");
            assert!(resp.batch_seq >= 1, "served without a batch_seq");
            if phase == 0 && resp.model == "tox21" {
                phase0_max_tox21_version = phase0_max_tox21_version.max(resp.version);
            }
            by_id.insert(resp.id, mol);
            responses.push(resp);
        }
        if phase == 0 {
            let cfg = registry.cfg("tox21").unwrap().clone();
            registry
                .swap_params("tox21", ParamSet::random_init(&cfg, 0xF00D))
                .unwrap();
        }
    }
    stop.store(true, Ordering::Relaxed);
    writer.join().unwrap();
    let snap = srv.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&plans_root);

    // ---- no mixed-version batch --------------------------------------
    let mut batches: BTreeMap<u64, Vec<&bspmm::coordinator::InferResponse>> = BTreeMap::new();
    for resp in &responses {
        batches.entry(resp.batch_seq).or_default().push(resp);
    }
    let mut tox21_versions = std::collections::BTreeSet::new();
    for (seq, group) in &batches {
        assert!(group.len() <= MAX_BATCH, "batch {seq} overflows capacity");
        let model = &group[0].model;
        let version = group[0].version;
        for resp in group {
            assert_eq!(&resp.model, model, "batch {seq} mixed models");
            assert_eq!(
                resp.version, version,
                "batch {seq} mixed parameter versions"
            );
        }
        if model == "tox21" {
            tox21_versions.insert(version);
        }
    }
    assert!(
        tox21_versions.len() >= 2,
        "hot swap never landed: versions {tox21_versions:?}"
    );
    assert!(
        tox21_versions.iter().any(|&v| v > phase0_max_tox21_version),
        "post-swap submissions kept serving the old version"
    );

    // ---- bit-identical replay on the stamped version ------------------
    // Rebuild each batch exactly as the server packed it (requests in
    // id order, padded to capacity) and run it on a fresh dispatcher
    // holding only the response's registered version. One dispatcher
    // per (model, version) so each compiles its plan once.
    let mut replayers: HashMap<(String, u64), MultiDispatcher> = HashMap::new();
    for group in batches.values() {
        let model = group[0].model.clone();
        let version = group[0].version;
        let pinned = registry
            .version(&model, version)
            .expect("served version is not in the registry history");
        assert_eq!(pinned.version, version);
        let md = replayers.entry((model.clone(), version)).or_insert_with(|| {
            let mut reg = ModelRegistry::new();
            reg.register(
                registry.cfg(&model).unwrap().clone(),
                pinned.params.clone(),
            )
            .unwrap();
            MultiDispatcher::new(Arc::new(reg), 1)
        });
        let mut ordered: Vec<_> = group.to_vec();
        ordered.sort_by_key(|r| r.id);
        let mols: Vec<&Molecule> = ordered.iter().map(|r| &by_id[&r.id]).collect();
        let cfg = registry.cfg(&model).unwrap();
        let mb =
            pack_molecules(&mols, MAX_BATCH, cfg.max_nodes, cfg.ell_width, cfg.n_out).unwrap();
        let (logits, v) = md.forward(&model, DispatchMode::Batched, &mb).unwrap();
        assert_eq!(v, 1, "replay registry holds exactly one version");
        for (bi, resp) in ordered.iter().enumerate() {
            assert_eq!(
                resp.logits,
                &logits[bi * cfg.n_out..(bi + 1) * cfg.n_out],
                "request {} (batch {}, version {}) logits diverge from \
                 a replay of its pinned version",
                resp.id,
                resp.batch_seq,
                version
            );
        }
    }

    // ---- warm multi-model steady state --------------------------------
    assert_eq!(snap.requests, 120);
    assert_eq!(snap.shed, 0);
    assert_eq!(
        snap.plans_built, 0,
        "a warm-started model compiled a plan under traffic"
    );
    assert!(snap.plans_warmed >= 2, "plans_warmed {}", snap.plans_warmed);
    assert!(snap.plan_replays > 0);
    assert!(snap.param_swaps >= 1, "param_swaps {}", snap.param_swaps);
    for m in MODELS {
        let pm = snap.model(m).expect("per-model metrics present");
        assert_eq!(pm.requests, 60, "model {m}");
        assert_eq!(pm.shed, 0, "model {m}");
        assert!(pm.batches > 0, "model {m}");
    }
}

#[test]
fn unknown_model_is_shed_without_execution() {
    let registry = two_model_registry();
    let srv = multi_model_server(&registry, None);
    let mut rng = Rng::new(0x0DD);
    let spec = MoleculeSpec::default();

    let rx = srv.submit_to("nope", Molecule::random(&mut rng, &spec));
    let resp = rx.recv_timeout(Duration::from_secs(30)).expect("shed reply");
    assert!(resp.shed);
    assert_eq!(resp.model, "nope");
    assert_eq!(resp.version, 0);
    assert_eq!(resp.batch_seq, 0);
    assert!(resp.logits.is_empty());

    // Registered models keep serving around the refusal.
    let rx = srv.submit_to("reaction100", Molecule::random(&mut rng, &spec));
    let ok = rx.recv_timeout(Duration::from_secs(120)).expect("response");
    assert!(!ok.shed);
    assert_eq!(ok.logits.len(), 100);

    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.shed, 1);
    let nm = snap.model("nope").expect("shed model appears in per-model metrics");
    assert_eq!(nm.shed, 1);
    assert_eq!(nm.requests, 0);
}

#[test]
fn warmed_multi_model_dispatcher_stays_within_plan_budget() {
    let registry = two_model_registry();
    let mut md = MultiDispatcher::new(Arc::clone(&registry), 1);
    let mut rng = Rng::new(0xA11C);
    let spec = MoleculeSpec::default();
    for m in MODELS {
        let cfg = registry.cfg(m).unwrap().clone();
        let mols: Vec<Molecule> = (0..MAX_BATCH)
            .map(|_| Molecule::random(&mut rng, &spec))
            .collect();
        let refs: Vec<&Molecule> = mols.iter().collect();
        let mb =
            pack_molecules(&refs, MAX_BATCH, cfg.max_nodes, cfg.ell_width, cfg.n_out).unwrap();
        // Twice: once to compile, once to replay.
        md.forward(m, DispatchMode::Batched, &mb).unwrap();
        md.forward(m, DispatchMode::Batched, &mb).unwrap();
    }
    let stats = md.plan_stats();
    assert_eq!(stats.plans_built, MODELS.len() as u64);
    assert_eq!(stats.replays, MODELS.len() as u64);
    assert!(stats.arena_bytes > 0);
    assert!(
        md.total_arena_bytes() <= md.plan_budget(),
        "arena {} exceeds global budget {}",
        md.total_arena_bytes(),
        md.plan_budget()
    );
    // Each tenant accounts for exactly its own plan.
    let per = md.per_tenant_stats();
    assert_eq!(per.len(), MODELS.len());
    for (tenant, s) in &per {
        assert_eq!(s.plans_built, 1, "tenant {tenant}");
        assert!(s.arena_bytes > 0, "tenant {tenant}");
    }
}
