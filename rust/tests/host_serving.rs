//! Coordinator end-to-end on the host-engine backend: the batched vs
//! per-sample dispatch contrast running entirely on the batched-SpMM
//! engine — no AOT artifacts required, so these run everywhere.

use std::path::PathBuf;
use std::time::Duration;

use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::trainer::Trainer;
use bspmm::coordinator::CloseRule;
use bspmm::gcn::backward;
use bspmm::gcn::ParamSet;
use bspmm::graph::dataset::{Dataset, DatasetKind};

fn host_server(mode: DispatchMode, max_batch: usize, wait_ms: u64) -> Server {
    Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("unused-for-host-backend"),
        model: "tox21".into(),
        mode,
        backend: ServeBackend::HostEngine { threads: 2 },
        max_batch,
        max_wait: Duration::from_millis(wait_ms),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: None,
        registry: None,
        plans_dir: None,
    })
    .expect("host server start")
}

#[test]
fn host_batched_server_answers_all_requests() {
    let srv = host_server(DispatchMode::Batched, 50, 20);
    let data = Dataset::generate(DatasetKind::Tox21, 75, 11);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    let mut ids = std::collections::HashSet::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.logits.len(), 12);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(ids.insert(resp.id), "duplicate response id");
    }
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 75);
    // 75 requests into batch-50 buckets: one full + one deadline flush.
    assert!(m.batches >= 2, "batches {}", m.batches);
    assert!(m.mean_batch_size > 1.0, "batching never engaged");
}

#[test]
fn host_per_sample_matches_batched_logits() {
    let srv_b = host_server(DispatchMode::Batched, 50, 10);
    let srv_s = host_server(DispatchMode::PerSample, 1, 0);
    let data = Dataset::generate(DatasetKind::Tox21, 8, 12);

    let collect = |srv: &Server| -> Vec<Vec<f32>> {
        let rxs: Vec<_> = data
            .samples
            .iter()
            .map(|s| srv.submit(s.mol.clone()))
            .collect();
        rxs.into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().logits)
            .collect()
    };
    let batched = collect(&srv_b);
    let single = collect(&srv_s);
    for (i, (a, b)) in batched.iter().zip(&single).enumerate() {
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                "request {i} logit {j}: batched {x} vs per-sample {y}"
            );
        }
    }
    let mb = srv_b.shutdown().unwrap();
    let ms = srv_s.shutdown().unwrap();
    // The structural contrast: same work, far fewer engine dispatches.
    assert!(mb.batches < ms.batches, "batched {} !< single {}", mb.batches, ms.batches);
}

#[test]
fn host_server_rejects_unknown_model() {
    let err = Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("unused"),
        model: "nope".into(),
        mode: DispatchMode::Batched,
        backend: ServeBackend::HostEngine { threads: 1 },
        max_batch: 50,
        max_wait: Duration::from_millis(1),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: None,
        registry: None,
        plans_dir: None,
    });
    assert!(err.is_err());
}

#[test]
fn host_shutdown_drains_pending_requests() {
    let srv = host_server(DispatchMode::Batched, 50, 10_000);
    // Long deadline: requests sit in the queue; shutdown must flush them.
    let data = Dataset::generate(DatasetKind::Tox21, 5, 13);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    std::thread::sleep(Duration::from_millis(50));
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 5);
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
    }
}

#[test]
fn host_trainer_trains_without_artifacts() {
    let mut tr = Trainer::new_host("tox21", 2).unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 12, 14);
    let idx: Vec<usize> = (0..12).collect();
    let (loss, acc) = tr.evaluate(&data, &idx).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert!((0.0..=1.0).contains(&acc), "acc {acc}");
    assert!(tr.dispatches > 0);

    // A full train step — fwd + engine-dispatch backward + SGD — runs
    // with no AOT artifacts, on any batch size.
    let mb = data
        .pack_batch(&idx[..8], tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();
    let before = tr.params.data.clone();
    let d0 = tr.dispatches;
    let l1 = tr.step_batched(&mb, 0.02).unwrap();
    assert!(l1.is_finite(), "step loss {l1}");
    assert_ne!(tr.params.data, before, "SGD did not move the parameters");
    // Same dispatch accounting as the train_step artifact: one per step.
    assert_eq!(tr.dispatches - d0, 1);

    // Non-batched: B per-sample grad dispatches + 1 apply, like the
    // grad_sample/apply_sgd artifact pair.
    let d1 = tr.dispatches;
    let l2 = tr.step_nonbatched(&mb, 0.02).unwrap();
    assert!(l2.is_finite());
    assert_eq!(tr.dispatches - d1, 9);

    // Evaluation still works on the updated parameters.
    let (loss2, _) = tr.evaluate(&data, &idx).unwrap();
    assert!(loss2.is_finite());

    // Empty batches must error instead of poisoning params (lr / 0).
    let empty = data
        .pack_batch(&[], tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();
    assert!(tr.step_batched(&empty, 0.02).is_err());
    assert!(tr.step_nonbatched(&empty, 0.02).is_err());
    assert!(tr.params.data.iter().all(|v| v.is_finite()));
}

#[test]
fn full_train_step_runs_on_exactly_one_pool_with_zero_new_spawns() {
    // The tentpole contract: a trainer owns one persistent worker pool
    // for its lifetime, every engine dispatch of a train step runs on
    // it, and nothing ever spawns a thread after pool construction —
    // the host-side analogue of keeping the device kernel resident
    // (DESIGN.md §9).
    let mut tr = Trainer::new_host("tox21", 4).unwrap();
    let exec = tr.executor().expect("host trainer has an executor").clone();
    let s0 = exec.stats();
    assert_eq!(s0.workers, 4);
    assert_eq!(s0.spawned_threads, 3, "pool spawns workers - 1 threads");
    assert_eq!(s0.dispatches, 0);

    let data = Dataset::generate(DatasetKind::Tox21, 8, 19);
    let idx: Vec<usize> = (0..8).collect();
    let mb = data
        .pack_batch(&idx, tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();
    tr.step_batched(&mb, 0.01).unwrap();
    let s1 = exec.stats();
    // 17 forward + 22 backward engine dispatches (DESIGN.md §8), all on
    // this one pool — the trainer constructed no other executor.
    assert_eq!(s1.dispatches - s0.dispatches, 39);
    assert_eq!(
        s1.spawned_threads, s0.spawned_threads,
        "a dispatch spawned a thread"
    );
    assert_eq!(s1.static_dispatches + s1.stealing_dispatches, s1.dispatches);

    // Further steps and forwards keep riding the same pool.
    tr.step_batched(&mb, 0.01).unwrap();
    tr.forward(&mb).unwrap();
    let s2 = exec.stats();
    assert_eq!(s2.dispatches - s1.dispatches, 39 + 17);
    assert_eq!(s2.spawned_threads, s0.spawned_threads);
}

#[test]
fn steady_state_training_builds_one_plan_and_never_grows_the_arena() {
    // The DESIGN.md §11 acceptance contract: a fixed-geometry training
    // loop compiles its train plan on step 1 and from step 2 on builds
    // zero new plans and allocates zero new arena buffers — every
    // intermediate is a bit-identical replay out of the workspace.
    let mut tr = Trainer::new_host("tox21", 2).unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 8, 23);
    let idx: Vec<usize> = (0..8).collect();
    let mb = data
        .pack_batch(&idx, tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();
    assert_eq!(tr.plan_stats().plans_built, 0);

    tr.step_batched(&mb, 0.01).unwrap();
    let s1 = tr.plan_stats();
    assert_eq!(s1.plans_built, 1);
    assert_eq!(s1.replays, 0);
    assert!(s1.arena_bytes > 0, "step 1 must populate the arena");

    for _ in 0..4 {
        tr.step_batched(&mb, 0.01).unwrap();
    }
    let s2 = tr.plan_stats();
    assert_eq!(s2.plans_built, 1, "a steady-state step rebuilt a plan");
    assert_eq!(s2.replays, 4);
    assert_eq!(
        s2.arena_bytes, s1.arena_bytes,
        "a steady-state step allocated a new arena buffer"
    );
    assert!(s2.arena_reuses > s1.arena_reuses);
    assert!(
        s2.zero_fills_elided > s1.zero_fills_elided,
        "overwrite-mode slots must skip their redundant zero-fills"
    );
}

#[test]
fn plan_cache_invalidates_on_geometry_change_only() {
    let mut tr = Trainer::new_host("tox21", 1).unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 10, 29);
    let idx: Vec<usize> = (0..8).collect();
    let mb8 = data
        .pack_batch(&idx, tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();
    let mb4 = data
        .pack_batch(&idx[..4], tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();

    tr.step_batched(&mb8, 0.01).unwrap();
    assert_eq!(tr.plan_stats().plans_built, 1);
    // Every SGD step updates the parameters; plans must survive that.
    tr.step_batched(&mb8, 0.01).unwrap();
    assert_eq!(tr.plan_stats().plans_built, 1);
    // Batch-size change is a new geometry -> a second plan.
    tr.step_batched(&mb4, 0.01).unwrap();
    assert_eq!(tr.plan_stats().plans_built, 2);
    // Returning to the first geometry replays its cached plan.
    let replays = tr.plan_stats().replays;
    tr.step_batched(&mb8, 0.01).unwrap();
    let s = tr.plan_stats();
    assert_eq!(s.plans_built, 2);
    assert_eq!(s.replays, replays + 1);
    // Explicit parameter replacement keeps plans too (only w_rep is
    // parameter-derived).
    let fresh = ParamSet::random_init(&tr.cfg, 77);
    tr.set_params(fresh);
    tr.step_batched(&mb8, 0.01).unwrap();
    assert_eq!(tr.plan_stats().plans_built, 2);
    // A node-bucket change is likewise a different geometry at the key
    // level (a trainer is pinned to one bucket, so check the key).
    let big = data
        .pack_batch(&idx, tr.cfg.max_nodes + 10, tr.cfg.ell_width)
        .unwrap();
    assert_ne!(
        backward::train_plan_key(&tr.cfg, &mb8),
        backward::train_plan_key(&tr.cfg, &big)
    );
}

#[test]
fn trainer_set_params_invalidates_readout_cache() {
    let data = Dataset::generate(DatasetKind::Tox21, 4, 16);
    let mut tr = Trainer::new_host("tox21", 1).unwrap();
    let mb = data
        .pack_batch(&[0, 1], tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();
    let before = tr.forward(&mb).unwrap(); // populates the w_rep cache
    let fresh = ParamSet::random_init(&tr.cfg, 99);
    tr.set_params(fresh.clone());
    let after = tr.forward(&mb).unwrap();
    assert_ne!(before, after, "stale readout cache survived set_params");
    // And the result matches a trainer built directly on the new params.
    let mut direct = Trainer::new_host("tox21", 1).unwrap();
    direct.set_params(fresh);
    assert_eq!(after, direct.forward(&mb).unwrap());
}

#[test]
fn host_nonbatched_step_matches_batched_step() {
    // Same initial params + same minibatch => near-identical new params
    // (up to accumulation-order rounding): the Table II decomposability
    // contract, now provable in-repo with no artifacts.
    let data = Dataset::generate(DatasetKind::Tox21, 10, 15);
    let idx: Vec<usize> = (0..8).collect();
    let mut tr_b = Trainer::new_host("tox21", 2).unwrap();
    let mb = data
        .pack_batch(&idx, tr_b.cfg.max_nodes, tr_b.cfg.ell_width)
        .unwrap();
    let loss_b = tr_b.step_batched(&mb, 0.05).unwrap();

    let mut tr_s = Trainer::new_host("tox21", 2).unwrap();
    let loss_s = tr_s.step_nonbatched(&mb, 0.05).unwrap();

    assert!(
        (loss_b - loss_s).abs() <= 1e-4 + 1e-4 * loss_b.abs(),
        "losses diverge: batched {loss_b} vs non-batched {loss_s}"
    );
    let max_diff = tr_b
        .params
        .data
        .iter()
        .zip(&tr_s.params.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 5e-4, "params diverge: max |diff| = {max_diff}");
}
