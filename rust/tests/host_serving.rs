//! Coordinator end-to-end on the host-engine backend: the batched vs
//! per-sample dispatch contrast running entirely on the batched-SpMM
//! engine — no AOT artifacts required, so these run everywhere.

use std::path::PathBuf;
use std::time::Duration;

use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::trainer::Trainer;
use bspmm::graph::dataset::{Dataset, DatasetKind};

fn host_server(mode: DispatchMode, max_batch: usize, wait_ms: u64) -> Server {
    Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("unused-for-host-backend"),
        model: "tox21".into(),
        mode,
        backend: ServeBackend::HostEngine { threads: 2 },
        max_batch,
        max_wait: Duration::from_millis(wait_ms),
        params_path: None,
    })
    .expect("host server start")
}

#[test]
fn host_batched_server_answers_all_requests() {
    let srv = host_server(DispatchMode::Batched, 50, 20);
    let data = Dataset::generate(DatasetKind::Tox21, 75, 11);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    let mut ids = std::collections::HashSet::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.logits.len(), 12);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(ids.insert(resp.id), "duplicate response id");
    }
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 75);
    // 75 requests into batch-50 buckets: one full + one deadline flush.
    assert!(m.batches >= 2, "batches {}", m.batches);
    assert!(m.mean_batch_size > 1.0, "batching never engaged");
}

#[test]
fn host_per_sample_matches_batched_logits() {
    let srv_b = host_server(DispatchMode::Batched, 50, 10);
    let srv_s = host_server(DispatchMode::PerSample, 1, 0);
    let data = Dataset::generate(DatasetKind::Tox21, 8, 12);

    let collect = |srv: &Server| -> Vec<Vec<f32>> {
        let rxs: Vec<_> = data
            .samples
            .iter()
            .map(|s| srv.submit(s.mol.clone()))
            .collect();
        rxs.into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().logits)
            .collect()
    };
    let batched = collect(&srv_b);
    let single = collect(&srv_s);
    for (i, (a, b)) in batched.iter().zip(&single).enumerate() {
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                "request {i} logit {j}: batched {x} vs per-sample {y}"
            );
        }
    }
    let mb = srv_b.shutdown().unwrap();
    let ms = srv_s.shutdown().unwrap();
    // The structural contrast: same work, far fewer engine dispatches.
    assert!(mb.batches < ms.batches, "batched {} !< single {}", mb.batches, ms.batches);
}

#[test]
fn host_server_rejects_unknown_model() {
    let err = Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("unused"),
        model: "nope".into(),
        mode: DispatchMode::Batched,
        backend: ServeBackend::HostEngine { threads: 1 },
        max_batch: 50,
        max_wait: Duration::from_millis(1),
        params_path: None,
    });
    assert!(err.is_err());
}

#[test]
fn host_shutdown_drains_pending_requests() {
    let srv = host_server(DispatchMode::Batched, 50, 10_000);
    // Long deadline: requests sit in the queue; shutdown must flush them.
    let data = Dataset::generate(DatasetKind::Tox21, 5, 13);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    std::thread::sleep(Duration::from_millis(50));
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 5);
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
    }
}

#[test]
fn host_trainer_evaluates_but_cannot_train() {
    let mut tr = Trainer::new_host("tox21", 2).unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 12, 14);
    let idx: Vec<usize> = (0..12).collect();
    let (loss, acc) = tr.evaluate(&data, &idx).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert!((0.0..=1.0).contains(&acc), "acc {acc}");
    assert!(tr.dispatches > 0);

    // Training needs the AOT gradient artifacts.
    let mb = data
        .pack_batch(&idx[..4], tr.cfg.max_nodes, tr.cfg.ell_width)
        .unwrap();
    let err = tr.step_nonbatched(&mb, 0.01);
    assert!(err.is_err());
    assert!(
        err.unwrap_err().to_string().contains("PJRT"),
        "error should say training needs PJRT artifacts"
    );
}
