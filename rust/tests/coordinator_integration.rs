//! Integration: the serving coordinator end-to-end over real artifacts.

use std::path::{Path, PathBuf};
use std::time::Duration;

use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::CloseRule;
use bspmm::graph::dataset::{Dataset, DatasetKind};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn server(mode: DispatchMode, max_batch: usize, wait_ms: u64) -> Option<Server> {
    let dir = artifacts_dir()?;
    Some(
        Server::start(ServerConfig {
            artifacts_dir: dir,
            model: "tox21".into(),
            mode,
            backend: ServeBackend::Pjrt,
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            close: CloseRule::SizeOrAge,
            queue_bound: 0,
            deadline: None,
            params_path: None,
            registry: None,
            plans_dir: None,
        })
        .expect("server start"),
    )
}

#[test]
fn batched_server_answers_all_requests() {
    let Some(srv) = server(DispatchMode::Batched, 50, 20) else { return };
    let data = Dataset::generate(DatasetKind::Tox21, 75, 11);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    let mut ids = std::collections::HashSet::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.logits.len(), 12);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(ids.insert(resp.id), "duplicate response id");
    }
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 75);
    // 75 requests into batch-50 buckets: one full + one deadline flush.
    assert!(m.batches >= 2, "batches {}", m.batches);
    assert!(m.mean_batch_size > 1.0, "batching never engaged");
}

#[test]
fn per_sample_server_matches_batched_logits() {
    let Some(srv_b) = server(DispatchMode::Batched, 50, 10) else { return };
    let Some(srv_s) = server(DispatchMode::PerSample, 1, 0) else { return };
    let data = Dataset::generate(DatasetKind::Tox21, 8, 12);

    let collect = |srv: &Server| -> Vec<Vec<f32>> {
        let rxs: Vec<_> = data
            .samples
            .iter()
            .map(|s| srv.submit(s.mol.clone()))
            .collect();
        rxs.into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().logits)
            .collect()
    };
    let batched = collect(&srv_b);
    let single = collect(&srv_s);
    for (i, (a, b)) in batched.iter().zip(&single).enumerate() {
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                "request {i} logit {j}: batched {x} vs per-sample {y}"
            );
        }
    }
    let mb = srv_b.shutdown().unwrap();
    let ms = srv_s.shutdown().unwrap();
    // The structural contrast: same work, far fewer device dispatches.
    assert!(mb.batches < ms.batches, "batched {} !< single {}", mb.batches, ms.batches);
}

#[test]
fn server_rejects_unknown_model() {
    let Some(dir) = artifacts_dir() else { return };
    let err = Server::start(ServerConfig {
        artifacts_dir: dir,
        model: "nope".into(),
        mode: DispatchMode::Batched,
        backend: ServeBackend::Pjrt,
        max_batch: 50,
        max_wait: Duration::from_millis(1),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: None,
        registry: None,
        plans_dir: None,
    });
    assert!(err.is_err());
}

#[test]
fn server_rejects_unsupported_batch_capacity() {
    let Some(dir) = artifacts_dir() else { return };
    let err = Server::start(ServerConfig {
        artifacts_dir: dir,
        model: "tox21".into(),
        mode: DispatchMode::Batched,
        backend: ServeBackend::Pjrt,
        max_batch: 33, // no fwd artifact with this capacity
        max_wait: Duration::from_millis(1),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: None,
        registry: None,
        plans_dir: None,
    });
    assert!(err.is_err());
}

#[test]
fn shutdown_drains_pending_requests() {
    let Some(srv) = server(DispatchMode::Batched, 50, 10_000) else { return };
    // Long deadline: requests sit in the queue; shutdown must flush them.
    let data = Dataset::generate(DatasetKind::Tox21, 5, 13);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    std::thread::sleep(Duration::from_millis(50));
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 5);
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
    }
}
