//! Fig. 10 reproduction: a batch of 100 matrices with *mixed* shapes —
//! dims uniform in [32, 256], nnz/row uniform in [1, 5] — everything
//! padded into the max bucket.
//!
//! Paper anchor: "At n_B = 1024, our Batched SpMM achieves up to 3.29x
//! speedup from the non-batched approaches." cuBLAS is excluded ("the
//! kernel only processes GEMM operations with same matrix sizes").
//!
//! Run: `cargo bench --bench fig10_mixed_batch`.

fn main() {
    if let Err(e) = bspmm::bench::figures::run_figure_bench(&["fig10"], false) {
        eprintln!("fig10 bench failed: {e:#}");
        std::process::exit(1);
    }
}
