//! Fig. 8 reproduction: SpMM throughput vs dense-input width `n_B` on
//! the GCN-application-shaped random dataset.
//!
//!   (a) dim=50, nnz/row=2, batch=50  — the Tox21 proxy
//!   (b) dim=50, nnz/row=2, batch=100 — the Reaction100 proxy
//!
//! Paper anchors: up to 9.27x vs the TF baseline at n_B=64 in (a),
//! 6.09x at n_B=512 in (b); 1.26x / 1.43x vs cuBLAS gemmBatched; nvprof
//! sm_efficiency 35.51% (non-batched) vs 89.07% / 87.87% (batched).
//!
//! Run: `cargo bench --bench fig8_spmm_sweep` (BENCH_QUICK=1 for a fast
//! pass). Results land in target/bench_results/fig8*.json.

fn main() {
    // Also report the simulated sm_efficiency contrast the paper quotes.
    let cm = bspmm::simulator::cost::CostModel::default();
    let tf = cm.tf_spmm_op(50, 2, 512);
    let st = cm.batched_spmm_st(100, 50, 2, 512);
    let csr = cm.batched_spmm_csr(100, 50, 2, 512);
    println!(
        "simulated sm_efficiency (dim=50, n_B=512): TF non-batched {:.1}% | \
         batched ST {:.1}% | batched CSR {:.1}%  (paper: 35.5% / 89.1% / 87.9%)\n",
        100.0 * tf.sm_efficiency(&cm.dev),
        100.0 * cm.dev.sm_efficiency(st.blocks),
        100.0 * cm.dev.sm_efficiency(csr.blocks),
    );
    if let Err(e) = bspmm::bench::figures::run_figure_bench(&["fig8a", "fig8b"], true) {
        eprintln!("fig8 bench failed: {e:#}");
        std::process::exit(1);
    }
}
