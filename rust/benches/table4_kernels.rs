//! Table IV reproduction (measured half): per-op execution time of the
//! graph-convolution layer's three kernels at the Tox21 layer geometry
//! (m=50, F=16 -> 64, minibatch 50), non-batched vs batched, on the
//! real CPU-PJRT runtime.
//!
//! The measured columns report the time to process the whole minibatch
//! through one op class: non-batched = 50 dispatches, batched = 1.
//! (The simulated-P100 half lives in `fig11_timeline`.)

use bspmm::bench::report::{render_comparison, save_json};
use bspmm::bench::workload::SpmmWorkload;
use bspmm::bench::BenchOpts;
use bspmm::runtime::{Runtime, Tensor};
use bspmm::util::json::{num, obj};
use bspmm::util::rng::Rng;
use bspmm::util::timer;

fn mean_us(opts: &BenchOpts, mut f: impl FnMut()) -> f64 {
    let s = timer::bench_adaptive(opts.warmup, opts.min_iters, opts.max_iters, opts.min_time_s, &mut f);
    s.iter().sum::<f64>() / s.len() as f64 * 1e6
}

fn run() -> anyhow::Result<()> {
    let rt = Runtime::new_default()?;
    let opts = BenchOpts::from_env();
    let mut rng = Rng::new(0xF1F);
    let (m, f_in, f_out, batch) = (50usize, 16usize, 64usize, 50usize);

    let randf = |rng: &mut Rng, n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal()).collect() };

    // ---- MatMul ---------------------------------------------------------
    let x1 = Tensor::f32(&[m, f_in], randf(&mut rng, m * f_in));
    let w = Tensor::f32(&[f_in, f_out], randf(&mut rng, f_in * f_out));
    let xb = Tensor::f32(&[m * batch, f_in], randf(&mut rng, m * batch * f_in));
    let mm1 = rt.executable("op_matmul_single")?;
    let mm_nb = mean_us(&opts, || {
        for _ in 0..batch {
            mm1.execute(&[x1.clone(), w.clone()]).unwrap();
        }
    });
    let mmb = rt.executable("op_matmul_batched")?;
    let mm_b = mean_us(&opts, || {
        mmb.execute(&[xb.clone(), w.clone()]).unwrap();
    });

    // ---- Add ------------------------------------------------------------
    let u1 = Tensor::f32(&[m, f_out], randf(&mut rng, m * f_out));
    let bias = Tensor::f32(&[f_out], randf(&mut rng, f_out));
    let ub = Tensor::f32(&[m * batch, f_out], randf(&mut rng, m * batch * f_out));
    let add1 = rt.executable("op_add_single")?;
    let add_nb = mean_us(&opts, || {
        for _ in 0..batch {
            add1.execute(&[u1.clone(), bias.clone()]).unwrap();
        }
    });
    let addb = rt.executable("op_add_batched")?;
    let add_b = mean_us(&opts, || {
        addb.execute(&[ub.clone(), bias.clone()]).unwrap();
    });

    // ---- SpMM (reuses the fig8a d50/z2/n64 artifacts) ---------------------
    let sw = rt.manifest.sweep("fig8a")?;
    let wld = SpmmWorkload::build(&sw, f_out)?;
    let st1 = rt.executable(&sw.st_single(f_out))?;
    let spmm_nb = mean_us(&opts, || {
        for b in 0..batch {
            st1.execute(&wld.st_single_inputs(b)).unwrap();
        }
    });
    let stb = rt.executable(&sw.st_batched(f_out))?;
    let st_inputs = wld.st_batched_inputs();
    let spmm_b = mean_us(&opts, || {
        stb.execute(&st_inputs).unwrap();
    });

    let fmt = |v: f64| format!("{v:.0}");
    let rows = vec![
        vec!["MatMul".into(), "1571".into(), fmt(mm_nb), "31".into(), fmt(mm_b), format!("{:.1}x", mm_nb / mm_b)],
        vec!["Add".into(), "1316".into(), fmt(add_nb), "23".into(), fmt(add_b), format!("{:.1}x", add_nb / add_b)],
        vec!["SpMM".into(), "1981".into(), fmt(spmm_nb), "190".into(), fmt(spmm_b), format!("{:.1}x", spmm_nb / spmm_b)],
    ];
    println!(
        "{}",
        render_comparison(
            "Table IV — per-op time per layer per minibatch [us], measured CPU-PJRT",
            &["op", "paper NB", "ours NB", "paper B", "ours B", "our speedup"],
            &rows,
        )
    );
    println!(
        "dispatches per op class: non-batched {batch}, batched 1 (paper: 150 vs 3 launches per layer)"
    );
    let j = obj(vec![
        ("matmul_nonbatched_us", num(mm_nb)),
        ("matmul_batched_us", num(mm_b)),
        ("add_nonbatched_us", num(add_nb)),
        ("add_batched_us", num(add_b)),
        ("spmm_nonbatched_us", num(spmm_nb)),
        ("spmm_batched_us", num(spmm_b)),
    ]);
    println!("  -> {}", save_json("table4_measured", &j)?.display());
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("table4 bench failed: {e:#}");
        std::process::exit(1);
    }
}
