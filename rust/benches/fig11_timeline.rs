//! Fig. 11 reproduction: the per-op execution timeline of one graph
//! convolution layer over one Tox21 minibatch (batch 50), non-batched
//! vs batched.
//!
//! Paper anchor: "the non-batched approach requires batchsize*3 = 150
//! times of CUDA kernel launches while the batched approach requires
//! only three."
//!
//! Two halves:
//! * simulated P100 timeline (the Fig. 11 bars + launch counts),
//! * measured CPU-PJRT dispatch counts from the real trainer, which
//!   show the same 150-vs-3-shaped collapse in executable dispatches.

use bspmm::bench::report::{render_comparison, save_json};
use bspmm::simulator::cost::CostModel;
use bspmm::simulator::timeline::{render_timeline, simulate_layer};
use bspmm::util::json::{num, obj};

fn main() {
    let cm = CostModel::default();
    let batch = 50;
    let nb = simulate_layer(&cm, batch, 50, 16, 64, 2, false);
    let b = simulate_layer(&cm, batch, 50, 16, 64, 2, true);

    println!("== Fig. 11 — one graph-convolution layer, one minibatch (simulated P100) ==\n");
    println!("non-batched ({} framework ops, {} kernel launches):", nb.events.len(), nb.launches);
    println!("{}", render_timeline(&nb, 64));
    println!("batched ({} framework ops, {} kernel launches):", b.events.len(), b.launches);
    println!("{}", render_timeline(&b, 64));

    let rows = vec![
        vec![
            "MatMul".to_string(),
            "1571".into(),
            format!("{:.0}", nb.matmul_us),
            "31".into(),
            format!("{:.0}", b.matmul_us),
        ],
        vec![
            "Add".to_string(),
            "1316".into(),
            format!("{:.0}", nb.add_us),
            "23".into(),
            format!("{:.0}", b.add_us),
        ],
        vec![
            "SpMM".to_string(),
            "1981".into(),
            format!("{:.0}", nb.spmm_us),
            "190".into(),
            format!("{:.0}", b.spmm_us),
        ],
    ];
    println!(
        "{}",
        render_comparison(
            "Table IV — per-op time per layer per minibatch [us]",
            &["op", "paper non-batched", "sim non-batched", "paper batched", "sim batched"],
            &rows,
        )
    );

    let j = obj(vec![
        ("nonbatched_matmul_us", num(nb.matmul_us)),
        ("nonbatched_add_us", num(nb.add_us)),
        ("nonbatched_spmm_us", num(nb.spmm_us)),
        ("nonbatched_launches", num(nb.launches as f64)),
        ("batched_matmul_us", num(b.matmul_us)),
        ("batched_add_us", num(b.add_us)),
        ("batched_spmm_us", num(b.spmm_us)),
        ("batched_launches", num(b.launches as f64)),
    ]);
    match save_json("fig11_table4_sim", &j) {
        Ok(p) => println!("  -> {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }
}
