//! Fig. 9 reproduction: batched-approach throughput under parameter
//! sweeps.
//!
//!   (a,b,c) dim in {32, 64, 128} at batch=100, nnz/row=2
//!   (d)     batch=50 (vs (b)'s 100) — the occupancy contrast
//!   (e,f)   nnz/row in {1, 5} — the ST-atomics vs CSR contrast
//!
//! Paper shapes to observe: CSR gains with dim while ST stays flat;
//! batch 100 beats batch 50; ST wins at nnz/row=1 but CSR is "best
//! performer on denser input sparse matrices"; cuBLAS relatively
//! stronger on denser matrices.
//!
//! Run: `cargo bench --bench fig9_param_sweep`.

fn main() {
    let keys = ["fig9a", "fig9b", "fig9c", "fig9d", "fig9e", "fig9f"];
    if let Err(e) = bspmm::bench::figures::run_figure_bench(&keys, true) {
        eprintln!("fig9 bench failed: {e:#}");
        std::process::exit(1);
    }
}
