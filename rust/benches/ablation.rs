//! Ablations over the design choices DESIGN.md calls out:
//!
//!  A1 — padding overhead of the fixed-bucket batched formats on a
//!       mixed-shape batch (measured: the price of "redundant threads
//!       terminate immediately" in our padded-slot form).
//!  A2 — shared-memory/cache-block budget sweep (simulated: how the
//!       Fig. 5 blocking decision moves with the budget).
//!  A3 — dynamic-batcher deadline sweep (measured on the serving
//!       coordinator: throughput vs latency vs occupancy).
//!  A4 — subWarp policy vs fixed-32 assignment (simulated CSR kernel).

use std::path::PathBuf;
use std::time::Duration;

use bspmm::bench::report::{render_comparison, save_json};
use bspmm::bench::workload::SpmmWorkload;
use bspmm::bench::BenchOpts;
use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::CloseRule;
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::runtime::artifact::SweepSpec;
use bspmm::runtime::Runtime;
use bspmm::simulator::cost::{plan_col_blocks_with_budget, subwarp, CostModel};
use bspmm::util::json::{num, obj, Json};
use bspmm::util::timer;

fn a1_padding_overhead(rt: &Runtime) -> anyhow::Result<Json> {
    println!("-- A1: padding overhead on mixed-shape batches --");
    let opts = BenchOpts::from_env();
    // Uniform batch at dim 64 vs the mixed fig10 batch padded to 256:
    // compare achieved GFLOPS per *real* non-zero.
    let uniform = rt.manifest.sweep("fig9b")?;
    let mixed = rt.manifest.sweep("fig10")?;
    let nb = 128;
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for sw in [&uniform, &mixed] {
        let w = SpmmWorkload::build(sw, nb)?;
        let exe = rt.executable(&sw.st_batched(nb))?;
        let inputs = w.st_batched_inputs();
        let s = timer::bench_adaptive(opts.warmup, opts.min_iters, opts.max_iters, opts.min_time_s, || {
            exe.execute(&inputs).unwrap();
        });
        let t = s.iter().sum::<f64>() / s.len() as f64;
        let pad = w.st.pad_fraction();
        rows.push(vec![
            sw.key.clone(),
            format!("{:.0}%", pad * 100.0),
            format!("{:.3}", w.gflops(t)),
            format!("{:.1}ms", t * 1e3),
        ]);
        out.push(obj(vec![
            ("sweep", Json::Str(sw.key.clone())),
            ("pad_fraction", num(pad)),
            ("gflops", num(w.gflops(t))),
            ("secs", num(t)),
        ]));
    }
    println!(
        "{}",
        render_comparison(
            "A1 padded-slot overhead (batched ST, n_B=128)",
            &["sweep", "pad fraction", "real GFLOPS", "time"],
            &rows,
        )
    );
    Ok(Json::Arr(out))
}

fn a2_block_budget() -> Json {
    println!("-- A2: cache-block budget sweep (simulated, dim=50..256, n_B=512) --");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    let cm = CostModel::default();
    for budget_kb in [8usize, 16, 32, 64] {
        for dim in [50usize, 128, 256] {
            let (bn, blocks) = plan_col_blocks_with_budget(dim, 512, budget_kb * 1024);
            // ST kernel time under that plan: nnz re-walked per block.
            let nnz = dim * 2;
            let vec_ops = nnz as f64 * (bn as f64 / 32.0).ceil() * blocks as f64;
            let us = vec_ops * 200.0 / (cm.dev.clock_ghz * 1e3);
            rows.push(vec![
                format!("{budget_kb}KB"),
                dim.to_string(),
                bn.to_string(),
                blocks.to_string(),
                format!("{us:.1}us"),
            ]);
            out.push(obj(vec![
                ("budget_kb", num(budget_kb as f64)),
                ("dim", num(dim as f64)),
                ("block_n", num(bn as f64)),
                ("col_blocks", num(blocks as f64)),
                ("kernel_us_per_matrix", num(us)),
            ]));
        }
    }
    println!(
        "{}",
        render_comparison(
            "A2 blocking plan vs budget",
            &["budget", "dim", "block_n", "col blocks", "ST work/matrix"],
            &rows,
        )
    );
    Json::Arr(out)
}

fn a3_batcher_deadline() -> anyhow::Result<Json> {
    println!("-- A3: batcher deadline sweep (tox21, 300 requests, capacity 50) --");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for wait_ms in [0u64, 2, 10, 50] {
        let srv = Server::start(ServerConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            model: "tox21".into(),
            mode: DispatchMode::Batched,
            backend: ServeBackend::Pjrt,
            max_batch: 50,
            max_wait: Duration::from_millis(wait_ms),
            close: CloseRule::SizeOrAge,
            queue_bound: 0,
            deadline: None,
            params_path: None,
            registry: None,
            plans_dir: None,
        })?;
        let data = Dataset::generate(DatasetKind::Tox21, 300, 0xAB);
        srv.submit(data.samples[0].mol.clone())
            .recv_timeout(Duration::from_secs(300))
            .map_err(|_| anyhow::anyhow!("warmup timeout"))?;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = data
            .samples
            .iter()
            .map(|s| srv.submit(s.mol.clone()))
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(300))
                .map_err(|_| anyhow::anyhow!("response timeout"))?;
        }
        let secs = t0.elapsed().as_secs_f64();
        let m = srv.shutdown()?;
        rows.push(vec![
            format!("{wait_ms}ms"),
            format!("{:.0}", m.requests as f64 / secs),
            format!("{:.1}ms", m.mean_latency_us / 1e3),
            format!("{:.1}ms", m.p95_latency_us as f64 / 1e3),
            format!("{:.0}%", m.mean_occupancy * 100.0),
            format!("{}", m.batches),
        ]);
        out.push(obj(vec![
            ("max_wait_ms", num(wait_ms as f64)),
            ("throughput_rps", num(m.requests as f64 / secs)),
            ("mean_latency_us", num(m.mean_latency_us)),
            ("p95_latency_us", num(m.p95_latency_us as f64)),
            ("occupancy", num(m.mean_occupancy)),
            ("batches", num(m.batches as f64)),
        ]));
    }
    println!(
        "{}",
        render_comparison(
            "A3 deadline vs throughput/latency/occupancy",
            &["max_wait", "req/s", "mean lat", "p95 lat", "occupancy", "batches"],
            &rows,
        )
    );
    Ok(Json::Arr(out))
}

fn a4_subwarp_policy() -> Json {
    println!("-- A4: subWarp policy vs fixed-32 (simulated CSR, dim=64, batch=100) --");
    let cm = CostModel::default();
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for nb in [4usize, 8, 16, 32, 128] {
        // Paper policy: subwarp(nb); naive: always 32 threads per row.
        let policy = subwarp(nb);
        let t_policy = csr_kernel_us(&cm, 64, 100, 2, nb, policy);
        let t_fixed = csr_kernel_us(&cm, 64, 100, 2, nb, 32);
        rows.push(vec![
            nb.to_string(),
            policy.to_string(),
            format!("{t_policy:.1}us"),
            format!("{t_fixed:.1}us"),
            format!("{:.2}x", t_fixed / t_policy),
        ]);
        out.push(obj(vec![
            ("nb", num(nb as f64)),
            ("subwarp", num(policy as f64)),
            ("kernel_us_policy", num(t_policy)),
            ("kernel_us_fixed32", num(t_fixed)),
        ]));
    }
    println!(
        "{}",
        render_comparison(
            "A4 subWarp sizing (kernel time, lower is better)",
            &["n_B", "subWarp", "policy", "fixed 32", "gain"],
            &rows,
        )
    );
    Json::Arr(out)
}

/// CSR kernel time with an explicit subwarp width (the A4 knob): with
/// sw threads per row, rows-per-block shrinks as sw grows, and lanes
/// beyond n_B are idle — exactly the §IV-A argument for the policy.
fn csr_kernel_us(cm: &CostModel, dim: usize, batch: usize, z: usize, nb: usize, sw: usize) -> f64 {
    let tpb = cm.dev.threads_per_block;
    let blocks = batch * (dim * sw).div_ceil(tpb).max(1);
    let rows_per_block = tpb / sw;
    let vec_ops = rows_per_block as f64 * z as f64 * (nb as f64 / sw as f64).ceil();
    // idle-lane waste when sw > nb
    let waste = if sw > nb { sw as f64 / nb as f64 } else { 1.0 };
    2.0 + cm.dev.waves(blocks) * vec_ops * 175.0 * waste / (cm.dev.clock_ghz * 1e3)
}

fn a5_kernel_variants(rt: &Runtime) -> anyhow::Result<Json> {
    println!("-- A5: L1 kernel-variant perf iteration (loop -> vec -> fused), measured --");
    let opts = BenchOpts::from_env();
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for (dim, z, nb, batch) in [(50usize, 2usize, 64usize, 50usize), (50, 2, 512, 100)] {
        let sw = SweepSpec {
            key: format!("perf_d{dim}_n{nb}"),
            dim,
            z,
            batch,
            nbs: vec![nb],
            mixed: false,
        };
        let w = SpmmWorkload::build(&sw, nb)?;
        for fmt in ["st", "csr"] {
            let mut point = vec![format!("{fmt} d{dim} n{nb} b{batch}")];
            let mut o = vec![
                ("format", Json::Str(fmt.into())),
                ("dim", num(dim as f64)),
                ("nb", num(nb as f64)),
                ("batch", num(batch as f64)),
            ];
            for variant in ["loop", "vec", "fused"] {
                let name = if variant == "fused" {
                    // default sweep artifacts are fused
                    format!("spmm_{fmt}_d{dim}_z{z}_n{nb}_b{batch}")
                } else {
                    format!("spmm_{fmt}_{variant}_d{dim}_z{z}_n{nb}_b{batch}")
                };
                let exe = rt.executable(&name)?;
                let inputs = if fmt == "st" {
                    w.st_batched_inputs()
                } else {
                    w.csr_batched_inputs()
                };
                let s = timer::bench_adaptive(
                    opts.warmup,
                    opts.min_iters,
                    opts.max_iters,
                    opts.min_time_s,
                    || {
                        exe.execute(&inputs).unwrap();
                    },
                );
                let t = s.iter().sum::<f64>() / s.len() as f64;
                point.push(format!("{:.2}ms", t * 1e3));
                o.push((
                    match variant {
                        "loop" => "loop_secs",
                        "vec" => "vec_secs",
                        _ => "fused_secs",
                    },
                    num(t),
                ));
            }
            rows.push(point);
            out.push(Json::Obj(
                o.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            ));
        }
    }
    println!(
        "{}",
        render_comparison(
            "A5 batched-kernel formulation (execute time, lower is better)",
            &["point", "loop", "vec", "fused"],
            &rows,
        )
    );
    Ok(Json::Arr(out))
}

fn main() {
    let rt = match Runtime::new_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable ({e:#}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let mut results = Vec::new();
    match a1_padding_overhead(&rt) {
        Ok(j) => results.push(("a1_padding", j)),
        Err(e) => eprintln!("A1 failed: {e:#}"),
    }
    results.push(("a2_block_budget", a2_block_budget()));
    match a3_batcher_deadline() {
        Ok(j) => results.push(("a3_batcher_deadline", j)),
        Err(e) => eprintln!("A3 failed: {e:#}"),
    }
    results.push(("a4_subwarp", a4_subwarp_policy()));
    match a5_kernel_variants(&rt) {
        Ok(j) => results.push(("a5_kernel_variants", j)),
        Err(e) => eprintln!("A5 failed: {e:#}"),
    }
    let j = Json::Obj(
        results
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    );
    match save_json("ablation", &j) {
        Ok(p) => println!("  -> {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }
}
