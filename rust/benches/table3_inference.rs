//! Table III reproduction: ChemGCN inference time, non-batched vs
//! batched dispatch, through the *serving coordinator* (dynamic batcher
//! + device thread) — the system-level realization of the paper's
//! batch-200 inference setting.
//!
//! Paper [sec] for the full dataset: Tox21 2.56 -> 1.97 (1.30x),
//! Reaction100 22.42 -> 16.32 (1.37x).
//!
//! Method: push N molecules through the server in both modes and report
//! wall time, throughput, mean latency, and batch occupancy; then
//! extrapolate to the paper's dataset sizes.

use std::path::PathBuf;
use std::time::Duration;

use bspmm::bench::report::{render_comparison, save_json};
use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::CloseRule;
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::util::json::{num, obj, Json};

struct Row {
    dataset: &'static str,
    paper_speedup: f64,
    nb_secs: f64,
    b_secs: f64,
    n: usize,
    paper_size: usize,
    occupancy: f64,
}

fn run_mode(
    kind: DatasetKind,
    mode: DispatchMode,
    max_batch: usize,
    n: usize,
) -> anyhow::Result<(f64, f64)> {
    let srv = Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        model: kind.model_name().into(),
        mode,
        backend: ServeBackend::Pjrt,
        max_batch,
        max_wait: Duration::from_millis(5),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: None,
        registry: None,
        plans_dir: None,
    })?;
    let data = Dataset::generate(kind, n, 0xCAFE);
    // Warm: one request through (compile + first dispatch).
    srv.submit(data.samples[0].mol.clone())
        .recv_timeout(Duration::from_secs(300))
        .map_err(|_| anyhow::anyhow!("warmup timed out"))?;

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow::anyhow!("response timed out"))?;
    }
    let secs = t0.elapsed().as_secs_f64();
    let m = srv.shutdown()?;
    Ok((secs, m.mean_occupancy))
}

fn measure(kind: DatasetKind, n: usize) -> anyhow::Result<Row> {
    // Paper: inference batch size 200 "to increase the throughput since
    // the batch size does not affect the accuracy".
    let (b_secs, occupancy) = run_mode(kind, DispatchMode::Batched, 200, n)?;
    let (nb_secs, _) = run_mode(kind, DispatchMode::PerSample, 1, n)?;
    Ok(Row {
        dataset: match kind {
            DatasetKind::Tox21 => "Tox21",
            DatasetKind::Reaction100 => "Reaction100",
        },
        paper_speedup: match kind {
            DatasetKind::Tox21 => 2.56 / 1.97,
            DatasetKind::Reaction100 => 22.42 / 16.32,
        },
        nb_secs,
        b_secs,
        n,
        paper_size: kind.paper_size(),
        occupancy,
    })
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut rows = Vec::new();
    match measure(DatasetKind::Tox21, if quick { 400 } else { 1000 }) {
        Ok(r) => rows.push(r),
        Err(e) => eprintln!("tox21 failed: {e:#}"),
    }
    if std::env::var("BENCH_SKIP_REACTION").is_err() {
        match measure(DatasetKind::Reaction100, if quick { 200 } else { 400 }) {
            Ok(r) => rows.push(r),
            Err(e) => eprintln!("reaction100 failed: {e:#}"),
        }
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let speedup = r.nb_secs / r.b_secs;
            let scale = r.paper_size as f64 / r.n as f64;
            vec![
                r.dataset.to_string(),
                format!("{:.2}x", r.paper_speedup),
                format!("{:.2}s", r.nb_secs),
                format!("{:.2}s", r.b_secs),
                format!("{speedup:.2}x"),
                format!("{:.0}s", r.nb_secs * scale),
                format!("{:.0}s", r.b_secs * scale),
                format!("{:.0}%", r.occupancy * 100.0),
            ]
        })
        .collect();
    println!(
        "{}",
        render_comparison(
            "Table III — inference time via serving coordinator (measured CPU-PJRT)",
            &[
                "dataset",
                "paper speedup",
                "ours NB",
                "ours B",
                "ours speedup",
                "extrap NB full",
                "extrap B full",
                "occupancy",
            ],
            &table,
        )
    );

    let j = Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("dataset", Json::Str(r.dataset.into())),
                    ("n", num(r.n as f64)),
                    ("nonbatched_secs", num(r.nb_secs)),
                    ("batched_secs", num(r.b_secs)),
                    ("paper_speedup", num(r.paper_speedup)),
                    ("our_speedup", num(r.nb_secs / r.b_secs)),
                    ("occupancy", num(r.occupancy)),
                ])
            })
            .collect(),
    );
    match save_json("table3_inference", &j) {
        Ok(p) => println!("  -> {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }
}
