//! Table II reproduction: ChemGCN training time, non-batched vs
//! batched dispatch, on the synthetic Tox21-like and Reaction100-like
//! datasets.
//!
//! Paper [sec]: Tox21 918.03 (GPU non-batched) -> 723.80 (batched),
//! 1.18x; Reaction100 3029.13 -> 1905.32, 1.59x.
//!
//! Method: measure steady-state per-step time in both modes over a few
//! minibatches, then extrapolate to the paper's full workload
//! (epochs x steps/epoch from Table I) — running 50 epochs x 7,862
//! molecules for every mode is not informative on a 1-core CPU box, and
//! the ratio is set by the per-step costs. Both the measured per-step
//! numbers and the extrapolation are reported and saved.
//!
//! BENCH_QUICK=1 uses fewer steps; reaction100 can be skipped with
//! BENCH_SKIP_REACTION=1 (its 512-wide layers are heavy on CPU).

use std::path::Path;

use bspmm::bench::report::{render_comparison, save_json};
use bspmm::coordinator::trainer::{TrainMode, Trainer};
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::util::json::{num, obj, Json};

struct Row {
    dataset: &'static str,
    paper_nonbatched_s: f64,
    paper_batched_s: f64,
    per_step_nonbatched_s: f64,
    per_step_batched_s: f64,
    steps_total: usize,
}

fn measure(
    kind: DatasetKind,
    epochs_paper: usize,
    steps_measured: usize,
) -> anyhow::Result<(f64, f64, usize)> {
    let dir = Path::new("artifacts");
    let mut tr = Trainer::new(dir, kind.model_name())?;
    let b = tr.cfg.train_batch;
    let n = b * steps_measured;
    let data = Dataset::generate(kind, n, 0xB00);
    let idx: Vec<usize> = (0..n).collect();

    // Warm both paths (compilation + first-dispatch costs excluded).
    let warm = data.pack_batch(&idx[..b], tr.cfg.max_nodes, tr.cfg.ell_width)?;
    tr.step_batched(&warm, 0.01)?;
    tr.step_nonbatched(&warm, 0.01)?;

    let t0 = std::time::Instant::now();
    let stats = tr.train_epoch(TrainMode::Batched, &data, &idx, 0.01, 0)?;
    let batched_per_step = t0.elapsed().as_secs_f64() / (n / b) as f64;
    assert!(stats.mean_loss.is_finite());

    let t0 = std::time::Instant::now();
    let stats = tr.train_epoch(TrainMode::NonBatched, &data, &idx, 0.01, 0)?;
    let nonbatched_per_step = t0.elapsed().as_secs_f64() / (n / b) as f64;
    assert!(stats.mean_loss.is_finite());

    // Paper workload: epochs x (dataset_size * 4/5 k-fold train split / b).
    let steps_total = epochs_paper * (kind.paper_size() * 4 / 5) / b;
    Ok((nonbatched_per_step, batched_per_step, steps_total))
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let steps = if quick { 2 } else { 4 };
    let mut rows: Vec<Row> = Vec::new();

    match measure(DatasetKind::Tox21, 50, steps) {
        Ok((nb, b, total)) => rows.push(Row {
            dataset: "Tox21",
            paper_nonbatched_s: 918.03,
            paper_batched_s: 723.80,
            per_step_nonbatched_s: nb,
            per_step_batched_s: b,
            steps_total: total,
        }),
        Err(e) => eprintln!("tox21 failed: {e:#}"),
    }
    if std::env::var("BENCH_SKIP_REACTION").is_err() {
        match measure(DatasetKind::Reaction100, 20, if quick { 1 } else { 2 }) {
            Ok((nb, b, total)) => rows.push(Row {
                dataset: "Reaction100",
                paper_nonbatched_s: 3029.13,
                paper_batched_s: 1905.32,
                per_step_nonbatched_s: nb,
                per_step_batched_s: b,
                steps_total: total,
            }),
            Err(e) => eprintln!("reaction100 failed: {e:#}"),
        }
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let speedup = r.per_step_nonbatched_s / r.per_step_batched_s;
            vec![
                r.dataset.to_string(),
                format!("{:.2}x", r.paper_nonbatched_s / r.paper_batched_s),
                format!("{:.1}ms", r.per_step_nonbatched_s * 1e3),
                format!("{:.1}ms", r.per_step_batched_s * 1e3),
                format!("{speedup:.2}x"),
                format!("{:.0}s", r.per_step_nonbatched_s * r.steps_total as f64),
                format!("{:.0}s", r.per_step_batched_s * r.steps_total as f64),
            ]
        })
        .collect();
    println!(
        "{}",
        render_comparison(
            "Table II — training time, non-batched vs batched dispatch (measured CPU-PJRT)",
            &[
                "dataset",
                "paper speedup",
                "ours NB/step",
                "ours B/step",
                "ours speedup",
                "extrap NB total",
                "extrap B total",
            ],
            &table,
        )
    );

    let j = Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("dataset", Json::Str(r.dataset.into())),
                    ("per_step_nonbatched_s", num(r.per_step_nonbatched_s)),
                    ("per_step_batched_s", num(r.per_step_batched_s)),
                    ("paper_speedup", num(r.paper_nonbatched_s / r.paper_batched_s)),
                    (
                        "our_speedup",
                        num(r.per_step_nonbatched_s / r.per_step_batched_s),
                    ),
                    ("steps_total_paper_workload", num(r.steps_total as f64)),
                ])
            })
            .collect(),
    );
    match save_json("table2_training", &j) {
        Ok(p) => println!("  -> {}", p.display()),
        Err(e) => eprintln!("save failed: {e}"),
    }
}
