//! Inert stand-in for the `xla` crate (PJRT/XLA bindings).
//!
//! The offline build environment carries no XLA toolchain, so this local
//! crate provides exactly the API surface `bspmm::runtime` consumes.
//! Everything that does not need a real XLA backend behaves faithfully:
//! `Literal` is a genuine host-side tensor container (construction,
//! reshape, shape queries, element readback, tuple decomposition), and
//! `PjRtClient::cpu()` succeeds so runtime construction and manifest
//! handling work. Only HLO parsing / compilation / execution return an
//! actionable error — those paths are gated behind `make artifacts` +
//! the real bindings (see DESIGN.md §Substitutions).

use std::fmt;

/// Error type mirroring the real crate's: stringly, `Send + Sync` so it
/// converts into `anyhow::Error` at the call sites.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn backend_unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: bspmm was built against the inert `xla` \
         stub crate (no XLA toolchain in this environment); swap in the \
         real PJRT bindings to execute AOT artifacts"
    ))
}

/// Element types an artifact tensor may carry. `#[non_exhaustive]` keeps
/// downstream matches future-proof exactly like the real bindings.
#[non_exhaustive]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Array shape of a non-tuple literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Clone, Debug)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: typed flat data + dims. Fully functional.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Element types `Literal` can marshal to/from host vectors.
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn wrap(data: Vec<Self>) -> Payload;
    fn read(payload: &Payload) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }

    fn wrap(data: Vec<Self>) -> Payload {
        Payload::F32(data)
    }

    fn read(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }

    fn wrap(data: Vec<Self>) -> Payload {
        Payload::I32(data)
    }

    fn read(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            payload: T::wrap(data.to_vec()),
        }
    }

    /// Tuple literal (what artifact executions return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            payload: Payload::Tuple(parts),
        }
    }

    fn numel(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if matches!(self.payload, Payload::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if want as usize != self.numel() {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elements) from {} elements",
                self.numel()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            payload: self.payload.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
            Payload::Tuple(_) => {
                return Err(Error("tuple literal has no array shape".into()))
            }
        };
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty,
        })
    }

    /// Read the elements back as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.payload)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.payload {
            Payload::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module. The stub cannot parse HLO text, so construction
/// fails with an actionable error (callers surface it verbatim).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(backend_unavailable(&format!(
            "parsing HLO text ({path})"
        )))
    }
}

/// Computation wrapper (proto -> compilable form).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer. In the stub it simply owns a host literal so
/// upload/readback round-trips work.
pub struct PjRtBuffer(Literal);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

/// Compiled executable handle. Never constructible through the stub's
/// failing `compile`, but the type (and its execute signatures) must
/// exist for the runtime layer to typecheck.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(backend_unavailable("artifact execution"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(backend_unavailable("artifact execution"))
    }
}

/// The PJRT client. Construction succeeds (so `Runtime::new` works and
/// manifest-only paths run); compilation is where the stub stops.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-host".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(backend_unavailable("artifact compilation"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer(literal.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn backend_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let buf = client
            .buffer_from_host_literal(None, &Literal::vec1(&[0.0f32]))
            .unwrap();
        assert!(buf.to_literal_sync().is_ok());
    }
}
