"""AOT pipeline: lower every executable the rust runtime needs to HLO text.

Run once via ``make artifacts`` (``cd python && python -m compile.aot
--out ../artifacts``).  Python never runs on the request path; after this
script finishes, the rust binary is self-contained.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs in --out:
  <name>.hlo.txt            one per executable (see manifest)
  <cfg>_params_init.bin     concatenated little-endian f32 initial params
  manifest.json             the ABI: every artifact's inputs/outputs/meta,
                            model param layouts, and the benchmark sweep
                            table shared with the rust bench harness
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import batched_spmm_csr, batched_spmm_st, blocking


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32 if dtype == "i32" else jnp.float32)


class Builder:
    def __init__(self, out_dir: str, only: str | None):
        self.out_dir = out_dir
        self.only = re.compile(only) if only else None
        self.artifacts = []
        self.n_written = 0
        self.n_skipped = 0

    def add(self, name, fn, in_specs, meta=None):
        """Lower fn(*in_specs) -> tuple and write <name>.hlo.txt.

        in_specs: [(arg_name, shape, dtype)]; outputs are recorded from
        the lowered signature so the manifest is always ABI-accurate.
        """
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"name": n, "dtype": d, "shape": list(s)} for n, s, d in in_specs
            ],
            "meta": meta or {},
        }
        if self.only and not self.only.search(name):
            if os.path.exists(path):
                # keep stale manifest info for skipped-but-present files
                entry["outputs"] = _shape_outputs(fn, in_specs)
                self.artifacts.append(entry)
                self.n_skipped += 1
            return
        lowered = jax.jit(fn).lower(*[spec(s, d) for _, s, d in in_specs])
        entry["outputs"] = [
            {"dtype": "i32" if o.dtype == jnp.int32 else "f32", "shape": list(o.shape)}
            for o in lowered.out_info
        ]
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        self.artifacts.append(entry)
        self.n_written += 1
        print(f"  [{self.n_written:3d}] {name}  ({len(text) // 1024} KiB)", flush=True)


def _shape_outputs(fn, in_specs):
    outs = jax.eval_shape(fn, *[spec(s, d) for _, s, d in in_specs])
    return [
        {"dtype": "i32" if o.dtype == jnp.int32 else "f32", "shape": list(o.shape)}
        for o in outs
    ]


# --------------------------------------------------------------------------
# Microbench artifacts (figures 8-10 + Table IV)
# --------------------------------------------------------------------------

# The sweep table: shared with the rust bench harness via the manifest so
# both sides iterate exactly the same experimental points.
SWEEPS = {
    # Preliminary evaluation (§V-A). dims/z follow the GCN application
    # dataset (Table I: max dim 50, molecular bond nnz/row ~ 2).
    "fig8a": {"dim": 50, "z": 2, "batch": 50, "nbs": [8, 16, 32, 64]},
    "fig8b": {"dim": 50, "z": 2, "batch": 100, "nbs": [64, 128, 256, 512]},
    # Parameter sweeps (Fig. 9): first row dims 32/64/128; (d) batch 50;
    # (e)/(f) nnz-per-row 1 and 5.
    "fig9a": {"dim": 32, "z": 2, "batch": 100, "nbs": [32, 128, 512]},
    "fig9b": {"dim": 64, "z": 2, "batch": 100, "nbs": [32, 128, 512]},
    "fig9c": {"dim": 128, "z": 2, "batch": 100, "nbs": [32, 128, 512]},
    "fig9d": {"dim": 64, "z": 2, "batch": 50, "nbs": [32, 128, 512]},
    "fig9e": {"dim": 64, "z": 1, "batch": 100, "nbs": [32, 128, 512]},
    "fig9f": {"dim": 64, "z": 5, "batch": 100, "nbs": [32, 128, 512]},
    # Mixed batch (Fig. 10): dims in [32, 256], z in [1, 5]; everything is
    # padded to the max (the paper's "redundant threads terminate
    # immediately" becomes measurable padding overhead here).
    "fig10": {"dim": 256, "z": 5, "batch": 100, "nbs": [128, 512, 1024],
              "mixed": True, "dim_range": [32, 256], "z_range": [1, 5]},
}


def st_fn(block_n=None, variant="fused"):
    def fn(ids, vals, dense):
        return (batched_spmm_st(ids, vals, dense, block_n=block_n, variant=variant),)
    return fn


def csr_fn(block_n=None, variant="fused"):
    def fn(rpt, colids, vals, dense):
        return (batched_spmm_csr(rpt, colids, vals, dense, block_n=block_n, variant=variant),)
    return fn


def gemm_fn(a, dense):
    return (jnp.einsum("bmk,bkn->bmn", a, dense),)


def add_bench_artifacts(b: Builder):
    batched_pts = set()
    gemm_pts = set()  # gemm is z-independent: dedup by (dim, nb, batch)
    single_pts = set()
    for sw in SWEEPS.values():
        for nb in sw["nbs"]:
            batched_pts.add((sw["dim"], sw["z"], nb, sw["batch"]))
            gemm_pts.add((sw["dim"], nb, sw["batch"]))
            single_pts.add((sw["dim"], sw["z"], nb))

    for dim, z, nb, batch in sorted(batched_pts):
        nnz = dim * z
        meta = {"kind": "spmm_bench", "dim": dim, "z": z, "nb": nb, "batch": batch}
        b.add(
            f"spmm_st_d{dim}_z{z}_n{nb}_b{batch}",
            st_fn(),
            [("ids", (batch, nnz, 2), "i32"), ("vals", (batch, nnz), "f32"),
             ("dense", (batch, dim, nb), "f32")],
            {**meta, "format": "st", "batched": True},
        )
        b.add(
            f"spmm_csr_d{dim}_z{z}_n{nb}_b{batch}",
            csr_fn(),
            [("rpt", (batch, dim + 1), "i32"), ("colids", (batch, nnz), "i32"),
             ("vals", (batch, nnz), "f32"), ("dense", (batch, dim, nb), "f32")],
            {**meta, "format": "csr", "batched": True},
        )
    for dim, nb, batch in sorted(gemm_pts):
        b.add(
            f"gemm_d{dim}_n{nb}_b{batch}",
            gemm_fn,
            [("a", (batch, dim, dim), "f32"), ("dense", (batch, dim, nb), "f32")],
            {"kind": "spmm_bench", "dim": dim, "nb": nb, "batch": batch,
             "format": "gemm", "batched": True},
        )

    # Perf-ablation artifacts: the "loop" (structurally-literal) and
    # "vec" (per-matrix grid) kernels at two representative points; the
    # default sweep artifacts use "fused". EXPERIMENTS.md §Perf records
    # the loop -> vec -> fused iteration at these points.
    for (dim, z, nb, batch) in [(50, 2, 64, 50), (50, 2, 512, 100)]:
        nnz = dim * z
        for variant in ("loop", "vec"):
            meta = {"kind": "spmm_perf_ablation", "dim": dim, "z": z, "nb": nb,
                    "batch": batch, "variant": variant}
            b.add(
                f"spmm_st_{variant}_d{dim}_z{z}_n{nb}_b{batch}",
                st_fn(variant=variant),
                [("ids", (batch, nnz, 2), "i32"), ("vals", (batch, nnz), "f32"),
                 ("dense", (batch, dim, nb), "f32")],
                {**meta, "format": "st", "batched": True},
            )
            b.add(
                f"spmm_csr_{variant}_d{dim}_z{z}_n{nb}_b{batch}",
                csr_fn(variant=variant),
                [("rpt", (batch, dim + 1), "i32"), ("colids", (batch, nnz), "i32"),
                 ("vals", (batch, nnz), "f32"), ("dense", (batch, dim, nb), "f32")],
                {**meta, "format": "csr", "batched": True},
            )

    for dim, z, nb in sorted(single_pts):
        nnz = dim * z
        meta = {"kind": "spmm_bench", "dim": dim, "z": z, "nb": nb, "batch": 1}
        b.add(
            f"spmm_st_d{dim}_z{z}_n{nb}_b1",
            st_fn(),
            [("ids", (1, nnz, 2), "i32"), ("vals", (1, nnz), "f32"),
             ("dense", (1, dim, nb), "f32")],
            {**meta, "format": "st", "batched": False},
        )
        b.add(
            f"spmm_csr_d{dim}_z{z}_n{nb}_b1",
            csr_fn(),
            [("rpt", (1, dim + 1), "i32"), ("colids", (1, nnz), "i32"),
             ("vals", (1, nnz), "f32"), ("dense", (1, dim, nb), "f32")],
            {**meta, "format": "csr", "batched": False},
        )


def add_table4_artifacts(b: Builder):
    """Per-op artifacts at the Tox21 layer-0 geometry (M=50, F=16 -> 64,
    train batch 50): Table IV times MatMul / Add / SpMM in non-batched
    (per sample-channel) vs batched (per channel) dispatch; the SpMM rows
    reuse the fig8a d50 z2 n64 artifacts."""
    m, f, o, batch = 50, 16, 64, 50

    def matmul(x, w):
        return (x @ w,)

    def addb(u, bias):
        return (u + bias,)

    def accum(c0, c1):
        return (c0 + c1,)

    b.add("op_matmul_single", matmul,
          [("x", (m, f), "f32"), ("w", (f, o), "f32")],
          {"kind": "op_bench", "op": "matmul", "batched": False})
    b.add("op_matmul_batched", matmul,
          [("x", (m * batch, f), "f32"), ("w", (f, o), "f32")],
          {"kind": "op_bench", "op": "matmul", "batched": True})
    b.add("op_add_single", addb,
          [("u", (m, o), "f32"), ("bias", (o,), "f32")],
          {"kind": "op_bench", "op": "add", "batched": False})
    b.add("op_add_batched", addb,
          [("u", (m * batch, o), "f32"), ("bias", (o,), "f32")],
          {"kind": "op_bench", "op": "add", "batched": True})
    b.add("op_accum_single", accum,
          [("c0", (m, o), "f32"), ("c1", (m, o), "f32")],
          {"kind": "op_bench", "op": "accum", "batched": False})
    b.add("op_accum_batched", accum,
          [("c0", (m * batch, o), "f32"), ("c1", (m * batch, o), "f32")],
          {"kind": "op_bench", "op": "accum", "batched": True})


# --------------------------------------------------------------------------
# Model artifacts
# --------------------------------------------------------------------------


def model_io_specs(cfg: M.GcnConfig, batch: int, with_labels: bool):
    io = [
        ("ell_cols", (batch, cfg.channels, cfg.max_nodes, cfg.ell_width), "i32"),
        ("ell_vals", (batch, cfg.channels, cfg.max_nodes, cfg.ell_width), "f32"),
        ("x", (batch, cfg.max_nodes, cfg.feat_dim), "f32"),
        ("mask", (batch, cfg.max_nodes), "f32"),
    ]
    if with_labels:
        io.append(("labels", (batch, cfg.n_out), "f32"))
    return io


def add_model_artifacts(b: Builder, cfg: M.GcnConfig, out_dir: str, only):
    specs_ = M.param_specs(cfg)
    pspecs = [(f"p:{n}", s, "f32") for n, s in specs_]

    def fwd(*args):
        params = list(args[: len(specs_)])
        ell_cols, ell_vals, x, mask = args[len(specs_):]
        return (M.forward(cfg, params, ell_cols, ell_vals, x, mask),)

    def tstep(*args):
        params = list(args[: len(specs_)])
        ell_cols, ell_vals, x, mask, labels, lr = args[len(specs_):]
        return M.train_step(cfg, params, ell_cols, ell_vals, x, mask, labels, lr)

    def gsample(*args):
        params = list(args[: len(specs_)])
        ell_cols, ell_vals, x, mask, labels = args[len(specs_):]
        return M.grad_sample(cfg, params, ell_cols, ell_vals, x, mask, labels)

    def sgd(*args):
        params = list(args[: len(specs_)])
        grads = list(args[len(specs_): 2 * len(specs_)])
        scale = args[-1]
        return M.apply_sgd(params, grads, scale)

    name = cfg.name
    meta = {"kind": "model", "model": name}
    b.add(f"{name}_fwd_b{cfg.infer_batch}", fwd,
          pspecs + model_io_specs(cfg, cfg.infer_batch, False),
          {**meta, "role": "fwd", "batch": cfg.infer_batch})
    b.add(f"{name}_fwd_b{cfg.train_batch}", fwd,
          pspecs + model_io_specs(cfg, cfg.train_batch, False),
          {**meta, "role": "fwd", "batch": cfg.train_batch})
    b.add(f"{name}_fwd_b1", fwd,
          pspecs + model_io_specs(cfg, 1, False),
          {**meta, "role": "fwd", "batch": 1})
    b.add(f"{name}_train_step_b{cfg.train_batch}", tstep,
          pspecs + model_io_specs(cfg, cfg.train_batch, True) + [("lr", (1,), "f32")],
          {**meta, "role": "train_step", "batch": cfg.train_batch})
    b.add(f"{name}_grad_sample", gsample,
          pspecs + model_io_specs(cfg, 1, True),
          {**meta, "role": "grad_sample", "batch": 1})
    b.add(f"{name}_apply_sgd", sgd,
          pspecs + [(f"g:{n}", s, "f32") for n, s in specs_] + [("scale", (1,), "f32")],
          {**meta, "role": "apply_sgd", "batch": 0})

    # Initial parameters: one flat little-endian f32 blob.
    bin_name = f"{name}_params_init.bin"
    if only is None or re.search(only, bin_name):
        params = M.init_params(cfg, seed=42)
        flat = np.concatenate([np.asarray(p, np.float32).ravel() for p in params])
        flat.astype("<f4").tofile(os.path.join(out_dir, bin_name))
        print(f"  [bin] {bin_name} ({flat.size} f32)")

    layout = []
    off = 0
    for n, s in specs_:
        size = int(np.prod(s))
        layout.append({"name": n, "shape": list(s), "offset": off, "size": size})
        off += size
    return {
        "name": name,
        "max_nodes": cfg.max_nodes,
        "feat_dim": cfg.feat_dim,
        "channels": cfg.channels,
        "hidden": list(cfg.hidden),
        "n_out": cfg.n_out,
        "loss": cfg.loss,
        "nnz_cap": cfg.nnz_cap,
        "ell_width": cfg.ell_width,
        "train_batch": cfg.train_batch,
        "infer_batch": cfg.infer_batch,
        "params": layout,
        "n_params": off,
        "init_file": bin_name,
        "artifact_fwd_infer": f"{name}_fwd_b{cfg.infer_batch}",
        "artifact_fwd_train": f"{name}_fwd_b{cfg.train_batch}",
        "artifact_fwd_sample": f"{name}_fwd_b1",
        "artifact_train_step": f"{name}_train_step_b{cfg.train_batch}",
        "artifact_grad_sample": f"{name}_grad_sample",
        "artifact_apply_sgd": f"{name}_apply_sgd",
    }


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex: lower only matching artifact names (dev aid)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    b = Builder(args.out, args.only)
    print("== model artifacts ==", flush=True)
    models = []
    for cfg in M.CONFIGS.values():
        models.append(add_model_artifacts(b, cfg, args.out, args.only))
    print("== bench artifacts (figures) ==", flush=True)
    add_bench_artifacts(b)
    print("== op artifacts (Table IV) ==", flush=True)
    add_table4_artifacts(b)

    manifest = {
        "version": 1,
        "artifacts": b.artifacts,
        "models": models,
        "sweeps": SWEEPS,
        "smem_budget_bytes": blocking.DEFAULT_SMEM_BUDGET_BYTES,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {b.n_written} artifacts ({b.n_skipped} skipped) + manifest.json")


if __name__ == "__main__":
    main()
