"""Batched SWA SpMM for the SparseTensor/COO format (paper §IV-A, Fig. 3).

GPU -> TPU adaptation (DESIGN.md §3 Hardware-Adaptation):

* The paper assigns one *thread block* per (matrix, column-block) and a
  ``subWarp`` of threads per non-zero.  Here one *Pallas grid step* is a
  (matrix, column-block) pair: ``grid = (batch, n_blocks)``.  Inside a
  grid step the per-non-zero work is a VPU vector op over the column
  block — the lane dimension plays the subWarp role, so the "assign up
  to 32 threads per nnz" policy becomes "assign the full lane slice of
  the block to each nnz", and ``subWarp``/occupancy only survive in the
  P100 cost model.
* Shared-memory output staging (Fig. 5-(a)) becomes the VMEM-resident
  output block owned by the grid step; cache blocking (Fig. 5-(b)) is
  the ``BlockSpec`` column split chosen by ``blocking.plan_blocks``.
* The GPU algorithm needs atomics because different subWarps may hit the
  same output row; a TPU core executes its grid sequentially, so the
  scatter-accumulate below is race-free while keeping the *same memory
  traffic pattern* (one output read-modify-write per nnz).

Padding slots carry ``val == 0`` at ``(0, 0)`` and therefore contribute
nothing — the analogue of the paper's "redundant threads terminate
immediately" load-imbalance handling, at the cost of one wasted FMA per
padded slot (measured in the rust ablation bench).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the rust runtime can
run the artifact.  Real-TPU performance is *estimated* (VMEM footprint +
MXU/VPU utilization) in DESIGN.md/EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blocking


def _st_kernel_vec(ids_ref, vals_ref, dense_ref, o_ref):
    """One grid step, vectorized: gather all nnz contributions at once
    and scatter-add them into the output block in a single op.

    This is the §Perf-optimized form (EXPERIMENTS.md §Perf, L1): the
    per-non-zero loop of Fig. 3 becomes one gather + one segment
    scatter-add over the whole block — the same memory-traffic pattern
    (each nnz reads one dense row and updates one output row, staged in
    VMEM), but expressed as lane-parallel vector ops instead of a
    sequential read-modify-write chain.  On the interpret/CPU path this
    removes the dominant per-iteration block-copy overhead; on a real
    TPU it is the natural VPU formulation.

    Block shapes (leading batch axis of extent 1):
      ids [1, NNZ, 2], vals [1, NNZ], dense [1, K, BN], o [1, M, BN].
    """
    dense = dense_ref[0]                         # [K, BN]
    ids = ids_ref[0]                             # [NNZ, 2]
    vals = vals_ref[0]                           # [NNZ]
    gathered = vals[:, None] * dense[ids[:, 1]]  # [NNZ, BN]
    m = o_ref.shape[1]
    out = jnp.zeros((m, dense.shape[1]), dense.dtype).at[ids[:, 0]].add(gathered)
    o_ref[0] = out


def _st_kernel_fused(ids_ref, vals_ref, dense_ref, o_ref):
    """One grid step covering the WHOLE batch (§Perf iteration 2): the
    paper's "single kernel launch for tens or hundreds of SpMM
    operations" taken literally — all matrices' non-zeros are flattened
    into one gather + one scatter-add over a [B*M, BN] output.

    Rationale: on the interpret/CPU path every grid step pays a fixed
    interpreter/dispatch cost (the measured analogue of a thread-block
    wave), so folding the batch axis out of the grid removes B-1 of
    those costs; the column-block axis remains the only grid dimension
    (the Fig. 5 cache-blocking structure is preserved).  Padding slots
    (val = 0 at (0,0)) scatter zeros into row b*M — harmless.

    Block shapes: ids [B, NNZ, 2], vals [B, NNZ], dense [B, K, BN],
    o [B, M, BN].
    """
    b, _, _ = ids_ref.shape
    k = dense_ref.shape[1]
    bn = dense_ref.shape[2]
    m = o_ref.shape[1]
    ids = ids_ref[...]
    vals = vals_ref[...]
    dense = dense_ref[...]
    sample = jnp.arange(b, dtype=ids.dtype)[:, None]
    flat_cols = (sample * k + ids[:, :, 1]).reshape(-1)
    flat_rows = (sample * m + ids[:, :, 0]).reshape(-1)
    gathered = vals.reshape(-1)[:, None] * dense.reshape(b * k, bn)[flat_cols]
    out = jnp.zeros((b * m, bn), dense.dtype).at[flat_rows].add(gathered)
    o_ref[...] = out.reshape(b, m, bn)


def _st_kernel_loop(ids_ref, vals_ref, dense_ref, o_ref):
    """One grid step: full SpMM of one matrix onto one column block.

    The structurally-faithful form of Fig. 3: one scatter-accumulate
    per non-zero (kept for the perf ablation; the vectorized kernel
    above is the default hot path).

    Block shapes (leading batch axis of extent 1):
      ids [1, NNZ, 2], vals [1, NNZ], dense [1, K, BN], o [1, M, BN].
    """
    nnz = ids_ref.shape[1]
    # Stage the dense input block once: every nnz re-reads rows of it, so
    # keeping it VMEM-resident is the Fig. 5 locality win.
    dense = dense_ref[0]
    o_ref[...] = jnp.zeros_like(o_ref)

    def body(i, _):
        rid = ids_ref[0, i, 0]
        cid = ids_ref[0, i, 1]
        val = vals_ref[0, i]
        # Gather B[cid, :], scale, scatter-add into C[rid, :].  This is
        # Fig. 3 line 9 with the subWarp strided loop replaced by one
        # lane-wide vector op; sequential grid => no atomics needed.
        row = o_ref[0, pl.dslice(rid, 1), :]
        contrib = val * jax.lax.dynamic_slice_in_dim(dense, cid, 1, axis=0)
        o_ref[0, pl.dslice(rid, 1), :] = row + contrib
        return 0

    jax.lax.fori_loop(0, nnz, body, 0)


@functools.partial(jax.jit, static_argnames=("m", "block_n", "variant"))
def batched_spmm_st(
    ids: jax.Array,
    vals: jax.Array,
    dense: jax.Array,
    *,
    m: int | None = None,
    block_n: int | None = None,
    variant: str = "fused",
) -> jax.Array:
    """Batched SpMM, SparseTensor format.

    Args:
      ids:   [B, NNZ, 2] int32 (row, col), zero-padded.
      vals:  [B, NNZ] f32, zero for padding slots.
      dense: [B, K, N] f32.
      m:     output rows per matrix (defaults to K — square adjacency).
      block_n: column block size; default chosen by the Fig. 5 planner.
      variant: "fused" (default: whole batch per grid step — the
        single-launch formulation), "vec" (per-matrix grid steps,
        vectorized body), or "loop" (the structurally-literal Fig. 3
        form). The non-default variants feed the §Perf ablation.

    Returns [B, M, N] f32.
    """
    b, nnz, _ = ids.shape
    _, k, n = dense.shape
    if m is None:
        m = k
    if block_n is None:
        plan = blocking.plan_blocks(m, n)
        block_n = plan.block_n if plan.staged else n
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    n_blocks = n // block_n

    if variant == "fused":
        return pl.pallas_call(
            _st_kernel_fused,
            grid=(n_blocks,),
            in_specs=[
                # Whole batch per grid step; only columns are blocked.
                pl.BlockSpec((b, nnz, 2), lambda ni: (0, 0, 0)),
                pl.BlockSpec((b, nnz), lambda ni: (0, 0)),
                pl.BlockSpec((b, k, block_n), lambda ni: (0, 0, ni)),
            ],
            out_specs=pl.BlockSpec((b, m, block_n), lambda ni: (0, 0, ni)),
            out_shape=jax.ShapeDtypeStruct((b, m, n), dense.dtype),
            interpret=True,
        )(ids, vals, dense)

    kernel = {"vec": _st_kernel_vec, "loop": _st_kernel_loop}[variant]
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            # Whole nnz list per matrix, reused for every column block.
            pl.BlockSpec((1, nnz, 2), lambda bi, ni: (bi, 0, 0)),
            pl.BlockSpec((1, nnz), lambda bi, ni: (bi, 0)),
            # Dense input: only the ni-th column slice is staged.
            pl.BlockSpec((1, k, block_n), lambda bi, ni: (bi, 0, ni)),
        ],
        out_specs=pl.BlockSpec((1, m, block_n), lambda bi, ni: (bi, 0, ni)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), dense.dtype),
        interpret=True,
    )(ids, vals, dense)
