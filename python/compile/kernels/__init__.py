"""L1 Pallas kernels: the paper's batched SpMM algorithms (TPU-adapted).

Exports:
  batched_spmm_st   — SparseTensor/COO variant (paper Fig. 3 + Fig. 5-a/b)
  batched_spmm_csr  — CSR variant, atomic-free (paper Fig. 4 + Fig. 5-c/d)
  blocking          — the cache-blocking / subWarp planner (paper §IV-B/C)
  ref               — pure-jnp oracles
"""

from . import blocking, ref
from .batched_spmm_csr import batched_spmm_csr
from .batched_spmm_ell import batched_spmm_ell
from .batched_spmm_st import batched_spmm_st

__all__ = [
    "batched_spmm_st", "batched_spmm_csr", "batched_spmm_ell",
    "blocking", "ref",
]
