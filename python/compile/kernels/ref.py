"""Pure-jnp correctness oracles for the batched SpMM kernels.

These are the ground truth the Pallas kernels (and, transitively, the
AOT artifacts the rust runtime executes) are validated against.  Each
oracle consumes the *padded batch* sparse formats described in
DESIGN.md §3:

  PaddedSparseTensor:  ids  [B, NNZ, 2] int32   (row, col) per non-zero
                       vals [B, NNZ]    f32     zero for padding slots
  PaddedCSR:           rpt    [B, M+1]  int32   row pointers
                       colids [B, NNZ]  int32   zero-padded
                       vals   [B, NNZ]  f32     zero-padded

Padding convention: an ST padding slot has val == 0 and ids == (0, 0),
so it contributes nothing; a CSR padding slot lies beyond rpt[-1] and is
masked out explicitly here (the Pallas kernel never reads it because the
row loop is bounded by rpt).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_st_ref(ids: jax.Array, vals: jax.Array, dense: jax.Array) -> jax.Array:
    """Batched SparseTensorDenseMatMul oracle (paper Fig. 2 semantics).

    ids [B,NNZ,2], vals [B,NNZ], dense [B,K,N] -> out [B,M,N].  For the
    square adjacency matrices of the GCN application M == K, so M is
    taken from dense; callers needing m != k use spmm_st_ref_m.
    """
    return spmm_st_ref_m(ids, vals, dense, dense.shape[1])


def spmm_st_ref_m(ids: jax.Array, vals: jax.Array, dense: jax.Array, m: int) -> jax.Array:
    def one(ids1, vals1, d1):
        rows = ids1[:, 0]
        cols = ids1[:, 1]
        gathered = vals1[:, None] * d1[cols]        # [NNZ, N]
        out = jnp.zeros((m, d1.shape[1]), d1.dtype)
        return out.at[rows].add(gathered)

    return jax.vmap(one)(ids, vals, dense)


def csr_row_of_slot(rpt1: jax.Array, nnz: int) -> jax.Array:
    """Map each non-zero slot index to its CSR row: row[i] is r such that
    rpt[r] <= i < rpt[r+1].  Slots beyond rpt[-1] map past the last row
    and are masked by the caller."""
    slots = jnp.arange(nnz)
    return jnp.searchsorted(rpt1, slots, side="right") - 1


def spmm_csr_ref(
    rpt: jax.Array, colids: jax.Array, vals: jax.Array, dense: jax.Array
) -> jax.Array:
    """Batched CSR SpMM oracle. rpt [B,M+1], colids/vals [B,NNZ],
    dense [B,K,N] -> out [B,M,N]."""
    m = rpt.shape[1] - 1
    nnz = colids.shape[1]

    def one(rpt1, colids1, vals1, d1):
        rows = csr_row_of_slot(rpt1, nnz)
        valid = jnp.arange(nnz) < rpt1[-1]
        v = jnp.where(valid, vals1, 0.0)
        gathered = v[:, None] * d1[jnp.where(valid, colids1, 0)]
        out = jnp.zeros((m, d1.shape[1]), d1.dtype)
        return out.at[jnp.where(valid, rows, 0)].add(gathered)

    return jax.vmap(one)(rpt, colids, vals, dense)


def spmm_ell_ref(ell_cols: jax.Array, ell_vals: jax.Array, dense: jax.Array) -> jax.Array:
    """Batched ELL SpMM oracle. ell_cols/ell_vals [B,M,R], dense [B,K,N]
    -> out [B,M,N]; padding slots have val == 0."""

    def one(cols1, vals1, d1):
        gathered = d1[cols1]                      # [M, R, N]
        return jnp.sum(vals1[..., None] * gathered, axis=1)

    return jax.vmap(one)(ell_cols, ell_vals, dense)


def st_to_ell(ids: jax.Array, vals: jax.Array, m: int, r: int):
    """Convert one PaddedSparseTensor matrix (no batch dim) to ELL
    arrays (numpy-side helper for tests)."""
    import numpy as np

    cols = np.zeros((m, r), np.int32)
    evals = np.zeros((m, r), np.float32)
    fill = np.zeros(m, np.int64)
    for i in range(vals.shape[0]):
        v = float(vals[i])
        if v == 0.0:
            continue
        row, col = int(ids[i, 0]), int(ids[i, 1])
        slot = fill[row]
        if slot >= r:
            raise ValueError(f"row {row} exceeds ELL width {r}")
        cols[row, slot] = col
        evals[row, slot] = v
        fill[row] += 1
    return cols, evals


def spmm_dense_ref(adj_dense: jax.Array, dense: jax.Array) -> jax.Array:
    """Batched GEMM baseline (the paper's cuBLAS gemmBatched stand-in):
    the sparse matrix densified and multiplied on the MXU path."""
    return jnp.einsum("bmk,bkn->bmn", adj_dense, dense)


def st_to_dense(ids: jax.Array, vals: jax.Array, m: int, k: int) -> jax.Array:
    """Densify a PaddedSparseTensor batch (for the GEMM baseline and for
    test cross-checks).  Duplicate (row, col) entries accumulate, which
    matches SpMM semantics."""

    def one(ids1, vals1):
        a = jnp.zeros((m, k), vals1.dtype)
        return a.at[ids1[:, 0], ids1[:, 1]].add(vals1)

    return jax.vmap(one)(ids, vals)


def csr_to_dense(rpt: jax.Array, colids: jax.Array, vals: jax.Array, k: int) -> jax.Array:
    """Densify a PaddedCSR batch."""
    m = rpt.shape[1] - 1
    nnz = colids.shape[1]

    def one(rpt1, colids1, vals1):
        rows = csr_row_of_slot(rpt1, nnz)
        valid = jnp.arange(nnz) < rpt1[-1]
        a = jnp.zeros((m, k), vals1.dtype)
        return a.at[
            jnp.where(valid, rows, 0), jnp.where(valid, colids1, 0)
        ].add(jnp.where(valid, vals1, 0.0))

    return jax.vmap(one)(rpt, colids, vals)
