"""Batched SWA SpMM for the CSR format (paper §IV-A, Fig. 4).

The CSR variant is the paper's *atomic-free* algorithm: a subWarp owns a
whole output row, so no two thread groups write the same output entry.
On the TPU the same structure becomes: one grid step owns a (matrix,
column-block) pair, iterates rows, accumulates each row in registers /
VMEM, and stores it exactly once — a pure streaming write pattern, which
is why the paper finds CSR keeps winning as ``nnz/row`` grows while the
SparseTensor variant degrades under atomic contention (Fig. 9-(e)/(f)).

Per Fig. 5-(c)/(d): shared memory only needs ``n_B`` floats per subWarp
(one output row), so cache blocking is applied *only when n_B itself is
large*; the planner here blocks on a per-row budget rather than the
whole-matrix budget the ST variant uses.

Padding: rows beyond a matrix's true row count have ``rpt[r] == rpt[r+1]``
(empty), so the inner loop body never executes for them — the direct
analogue of the paper's "redundant threads terminate immediately".

See batched_spmm_st.py for the general GPU->TPU adaptation notes and the
``interpret=True`` rationale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blocking


def _csr_kernel_vec(rpt_ref, colids_ref, vals_ref, dense_ref, o_ref):
    """One grid step, vectorized (§Perf-optimized, see the ST kernel's
    docstring): slot -> row mapping via searchsorted, one gather over
    the dense block, one masked segment scatter-add, one block store.
    Still atomic-free in spirit — every output row is produced by
    exactly one logical owner; the scatter-add here is the lane-parallel
    expression of the per-row accumulate of Fig. 4.
    """
    rpt = rpt_ref[0]                                    # [M+1]
    colids = colids_ref[0]                              # [NNZ]
    vals = vals_ref[0]                                  # [NNZ]
    dense = dense_ref[0]                                # [K, BN]
    nnz = colids.shape[0]
    m = o_ref.shape[1]
    slots = jnp.arange(nnz)
    rows = jnp.searchsorted(rpt, slots, side="right") - 1
    valid = slots < rpt[m]
    v = jnp.where(valid, vals, 0.0)
    gathered = v[:, None] * dense[jnp.where(valid, colids, 0)]
    out = jnp.zeros((m, dense.shape[1]), dense.dtype).at[
        jnp.where(valid, rows, 0)
    ].add(gathered)
    o_ref[0] = out


def _csr_kernel_fused(rpt_ref, colids_ref, vals_ref, dense_ref, o_ref):
    """One grid step covering the WHOLE batch (§Perf iteration 2; see
    the ST kernel's `_st_kernel_fused` docstring): vmapped slot->row
    mapping, then one flattened gather + masked scatter-add.
    Block shapes: rpt [B, M+1], colids/vals [B, NNZ],
    dense [B, K, BN], o [B, M, BN]."""
    rpt = rpt_ref[...]
    colids = colids_ref[...]
    vals = vals_ref[...]
    dense = dense_ref[...]
    b, nnz = colids.shape
    k = dense.shape[1]
    bn = dense.shape[2]
    m = o_ref.shape[1]
    slots = jnp.arange(nnz)
    rows = jax.vmap(lambda r: jnp.searchsorted(r, slots, side="right") - 1)(rpt)
    valid = slots[None, :] < rpt[:, -1:]
    v = jnp.where(valid, vals, 0.0)
    sample = jnp.arange(b)[:, None]
    flat_cols = (sample * k + jnp.where(valid, colids, 0)).reshape(-1)
    flat_rows = (sample * m + jnp.where(valid, rows, 0)).reshape(-1)
    gathered = v.reshape(-1)[:, None] * dense.reshape(b * k, bn)[flat_cols]
    out = jnp.zeros((b * m, bn), dense.dtype).at[flat_rows].add(gathered)
    o_ref[...] = out.reshape(b, m, bn)


def _csr_kernel_loop(rpt_ref, colids_ref, vals_ref, dense_ref, o_ref):
    """One grid step: CSR SpMM of one matrix onto one column block —
    the structurally-literal Fig. 4 form (row loop, register
    accumulator, single store per row); kept for the perf ablation.

    Block shapes (leading batch axis of extent 1):
      rpt [1, M+1], colids [1, NNZ], vals [1, NNZ],
      dense [1, K, BN], o [1, M, BN].
    """
    m = o_ref.shape[1]
    bn = o_ref.shape[2]
    dense = dense_ref[0]

    def row_body(r, _):
        lo = rpt_ref[0, r]
        hi = rpt_ref[0, r + 1]

        def nz_body(nzid, acc):
            cid = colids_ref[0, nzid]
            val = vals_ref[0, nzid]
            # Fig. 4 lines 6-9: one FMA of B[cid, block] into the row
            # accumulator; the subWarp-strided j loop is one vector op.
            return acc + val * jax.lax.dynamic_slice_in_dim(dense, cid, 1, axis=0)

        acc = jax.lax.fori_loop(lo, hi, nz_body, jnp.zeros((1, bn), dense.dtype))
        # Single store per row: the atomic-free property of the CSR
        # algorithm (no other grid step touches this row of this block).
        o_ref[0, pl.dslice(r, 1), :] = acc
        return 0

    jax.lax.fori_loop(0, m, row_body, 0)


@functools.partial(jax.jit, static_argnames=("block_n", "variant"))
def batched_spmm_csr(
    rpt: jax.Array,
    colids: jax.Array,
    vals: jax.Array,
    dense: jax.Array,
    *,
    block_n: int | None = None,
    variant: str = "fused",
) -> jax.Array:
    """Batched SpMM, CSR format.

    Args:
      rpt:    [B, M+1] int32 row pointers (monotone, rpt[0] == 0).
      colids: [B, NNZ] int32, zero-padded beyond rpt[-1].
      vals:   [B, NNZ] f32, zero-padded beyond rpt[-1].
      dense:  [B, K, N] f32.
      block_n: column block size; default per the Fig. 5-(d) per-row plan.
      variant: "fused" (default: whole batch per grid step), "vec"
        (per-matrix grid steps), or "loop" (literal Fig. 4) — the
        non-default variants feed the §Perf ablation.

    Returns [B, M, N] f32.
    """
    b, m_plus_1 = rpt.shape
    m = m_plus_1 - 1
    nnz = colids.shape[1]
    _, k, n = dense.shape
    if block_n is None:
        # CSR stages one row (not the whole output) per subWarp, so the
        # blocking criterion is per-row: TB/subWarp rows of n floats.
        # With our grid-step model the practical budget is the dense
        # input block, so reuse the planner with the K x N staging cost.
        plan = blocking.plan_blocks(max(k, 1), n)
        block_n = plan.block_n if plan.staged else n
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    n_blocks = n // block_n

    if variant == "fused":
        return pl.pallas_call(
            _csr_kernel_fused,
            grid=(n_blocks,),
            in_specs=[
                pl.BlockSpec((b, m_plus_1), lambda ni: (0, 0)),
                pl.BlockSpec((b, nnz), lambda ni: (0, 0)),
                pl.BlockSpec((b, nnz), lambda ni: (0, 0)),
                pl.BlockSpec((b, k, block_n), lambda ni: (0, 0, ni)),
            ],
            out_specs=pl.BlockSpec((b, m, block_n), lambda ni: (0, 0, ni)),
            out_shape=jax.ShapeDtypeStruct((b, m, n), dense.dtype),
            interpret=True,
        )(rpt, colids, vals, dense)

    kernel = {"vec": _csr_kernel_vec, "loop": _csr_kernel_loop}[variant]
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, m_plus_1), lambda bi, ni: (bi, 0)),
            pl.BlockSpec((1, nnz), lambda bi, ni: (bi, 0)),
            pl.BlockSpec((1, nnz), lambda bi, ni: (bi, 0)),
            pl.BlockSpec((1, k, block_n), lambda bi, ni: (bi, 0, ni)),
        ],
        out_specs=pl.BlockSpec((1, m, block_n), lambda bi, ni: (bi, 0, ni)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), dense.dtype),
        interpret=True,
    )(rpt, colids, vals, dense)
