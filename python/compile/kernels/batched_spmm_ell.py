"""Batched SpMM in ELL (padded per-row) format — gather-only.

§Perf iteration 3 (EXPERIMENTS.md §Perf, L1) and the final hardware
adaptation of the paper's CSR variant: the CSR kernel's row-parallel,
atomic-free structure, expressed with *no scatter at all*.  Each output
row gathers its ≤R source rows of the dense input and reduces them —
the formulation both TPUs (no efficient scatter; gather + VPU reduce is
native) and the old XLA CPU runtime (whose scatter emitter copies the
whole output per index) want.  This is also the lineage of the ELLR-T
SpMM of Vázquez et al. that the paper's related-work section discusses:
the format conversion the paper avoids on GPU is a one-time, build-side
cost here (the rust coordinator packs molecules directly into ELL).

Format:  ell_cols [B, M, R] int32, ell_vals [B, M, R] f32 — row m of
matrix b multiplies dense rows ``ell_cols[b, m, :]`` by
``ell_vals[b, m, :]`` and sums.  Padding slots have val = 0, col = 0.

The whole batch is one grid step (the fused single-launch formulation);
column blocking via BlockSpec remains the Fig. 5 cache-blocking analog
and also caps the gathered intermediate at [B, M, R, BN].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blocking


def _ell_kernel_fused(cols_ref, vals_ref, dense_ref, o_ref):
    """Block shapes: cols [B, M, R], vals [B, M, R], dense [B, K, BN],
    o [B, M, BN]."""
    cols = cols_ref[...]
    vals = vals_ref[...]
    dense = dense_ref[...]
    b, m, r = cols.shape
    k = dense.shape[1]
    bn = dense.shape[2]
    flat = dense.reshape(b * k, bn)
    sample = jnp.arange(b, dtype=cols.dtype)[:, None, None]
    gathered = flat[(sample * k + cols).reshape(-1)].reshape(b, m, r, bn)
    o_ref[...] = jnp.sum(vals[..., None] * gathered, axis=2)


@functools.partial(jax.jit, static_argnames=("block_n",))
def batched_spmm_ell(
    ell_cols: jax.Array,
    ell_vals: jax.Array,
    dense: jax.Array,
    *,
    block_n: int | None = None,
) -> jax.Array:
    """Batched SpMM, ELL format: out [B, M, N]."""
    b, m, _ = ell_cols.shape
    _, k, n = dense.shape
    if block_n is None:
        plan = blocking.plan_blocks(m, n)
        block_n = plan.block_n if plan.staged else n
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    n_blocks = n // block_n

    return pl.pallas_call(
        _ell_kernel_fused,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((b, m, ell_cols.shape[2]), lambda ni: (0, 0, 0)),
            pl.BlockSpec((b, m, ell_vals.shape[2]), lambda ni: (0, 0, 0)),
            pl.BlockSpec((b, k, block_n), lambda ni: (0, 0, ni)),
        ],
        out_specs=pl.BlockSpec((b, m, block_n), lambda ni: (0, 0, ni)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), dense.dtype),
        interpret=True,
    )(ell_cols, ell_vals, dense)
