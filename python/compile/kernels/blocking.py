"""Cache-blocking / resource-assignment planning (paper §IV-B, §IV-C).

The paper stages the SpMM output matrix in GPU shared memory (32 KB per
thread block in their running example) and, when ``m_A * n_B * 4B``
exceeds that budget, splits the output along the column dimension
(Fig. 5-(b)/(d)).  On the TPU the analogous scarce resource is VMEM: a
Pallas grid step owns a VMEM-resident output block, and ``BlockSpec``
column blocking plays exactly the role of the paper's cache blocking.

This module is the *host-side planner*: given matrix shapes it decides
the column block size (and therefore the grid), mirroring the three
cases of §IV-C:

  1. whole output fits               -> one column block
  2. a column slice fits             -> ``n_blocks`` column blocks
  3. matrix too large to stage at all -> caller falls back to the
     unblocked (direct-HBM) kernel; with the paper's 32 KB budget this
     only happens for ``m_A > 8192``, outside the GCN regime.

It also ports the paper's ``subWarp`` policy (§IV-A) verbatim; on the
TPU this quantity sizes the *lane slice* assigned to one non-zero /
row rather than a thread group, and it drives the P100 cost model on
the rust side (which re-implements the same formula — kept in sync by
``python/tests/test_blocking.py`` golden values).
"""

from __future__ import annotations

import dataclasses

# The paper's running example assigns 32 KB of shared memory per thread
# block ("we assume that the size of shared memory to each SpMM operation
# in single precision is 32KB").  We use the same default so the planning
# decisions (and therefore the artifact grids) match the paper's cases.
DEFAULT_SMEM_BUDGET_BYTES = 32 * 1024

# TPU VMEM is ~16 MB/core; we stage at most this much output per grid
# step so double buffering of the dense input still fits.
DEFAULT_VMEM_BUDGET_BYTES = 4 * 1024 * 1024

WARP_SIZE = 32


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (x >= 1)."""
    if x < 1:
        raise ValueError(f"next_pow2 requires x >= 1, got {x}")
    return 1 << (x - 1).bit_length()


def subwarp(n_b: int) -> int:
    """The paper's subWarp policy (§IV-A):

        subWarp = 32                       if n_B > 16
                  min 2^p s.t. n_B <= 2^p  if n_B <= 16
    """
    if n_b > 16:
        return WARP_SIZE
    return next_pow2(n_b)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Column-blocking decision for one (batched) SpMM.

    Attributes:
      m:          output row count (per matrix).
      n_b:        dense-input column count.
      block_n:    columns per block (block_n == n_b means case 1).
      n_blocks:   number of column blocks (grid extent along columns).
      staged:     False means case 3 — output cannot be staged at all.
    """

    m: int
    n_b: int
    block_n: int
    n_blocks: int
    staged: bool

    @property
    def bytes_per_block(self) -> int:
        return self.m * self.block_n * 4


def plan_blocks(
    m: int,
    n_b: int,
    budget_bytes: int = DEFAULT_SMEM_BUDGET_BYTES,
    min_block_n: int = 8,
) -> BlockPlan:
    """Decide the column block size for an ``m x n_b`` f32 output.

    Case 1: the whole output fits in the budget -> single block.
    Case 2: halve the column count (powers of two, matching the paper's
            "divided into two sub matrices ... p sub matrices") until a
            slice fits, but never below ``min_block_n`` columns (a TPU
            lane-efficiency floor; the paper's floor is one subWarp).
    Case 3: even the narrowest slice does not fit -> not staged.
    """
    if m <= 0 or n_b <= 0:
        raise ValueError(f"plan_blocks requires positive dims, got m={m} n_b={n_b}")
    if m * n_b * 4 <= budget_bytes:
        return BlockPlan(m=m, n_b=n_b, block_n=n_b, n_blocks=1, staged=True)
    block_n = next_pow2(n_b) // 2
    while block_n >= min_block_n:
        if m * block_n * 4 <= budget_bytes:
            n_blocks = -(-n_b // block_n)  # ceil div
            return BlockPlan(m=m, n_b=n_b, block_n=block_n, n_blocks=n_blocks, staged=True)
        block_n //= 2
    return BlockPlan(m=m, n_b=n_b, block_n=n_b, n_blocks=1, staged=False)


def plan_batch(
    ms: list[int],
    n_b: int,
    budget_bytes: int = DEFAULT_SMEM_BUDGET_BYTES,
) -> BlockPlan:
    """Batch-level plan (§IV-C): cache blocking is applied to *all* SpMM
    operations in the batch if *any* output cannot be staged unblocked —
    the plan is driven by ``max m_A * n_B`` over the batch."""
    if not ms:
        raise ValueError("plan_batch requires a non-empty batch")
    return plan_blocks(max(ms), n_b, budget_bytes=budget_bytes)
