"""L2: the ChemGCN model (paper §IV-D) in JAX, calling the L1 kernels.

The graph-convolution layer follows Fig. 6 / Fig. 7:

    for ch in channels:                       # O(channel) ops (Fig. 7)
        U  = MatMul(X, W[ch])                 # one batched einsum
        B  = Add(bias[ch], U)
        C += BatchedSpMM(A[:, ch], B)         # L1 Pallas kernel
    Y = GraphNorm(C); H = ReLU(Y)

Two dispatch formulations share this exact function:

* **batched**  — the whole minibatch in one executable (one PJRT
  execute per step), the Fig. 7 path;
* **non-batched** — the same function traced at batch=1; the rust
  coordinator issues one execute per sample (per-sample grads are
  averaged host-side), the Fig. 6 path.

Design deviations from the paper (recorded in DESIGN.md §7):

* BatchNorm -> **per-graph masked normalization** ("GraphNorm"): stats
  are computed over each graph's own (masked) nodes instead of the
  minibatch.  This makes the model *exactly* decomposable per sample,
  so batched and non-batched modes compute the same function and the
  timing comparison (Tables II/III) is apples-to-apples.  The paper
  itself notes batching "has no effect on the accuracy"; normalization
  choice is orthogonal to the batching contribution.
* The backward pass of SpMM is itself a Batched SpMM with the
  transposed adjacency — molecular adjacency is symmetric (undirected
  bonds + self loops), so the same arrays serve fwd and bwd ("The
  Batched SpMM is also applied to backward propagation", §IV-D).
* Adjacency reaches the model in **ELL** (padded per-row) form and the
  SpMM runs through the gather-only kernel — the TPU-native expression
  of the paper's atomic-free CSR variant (see
  kernels/batched_spmm_ell.py and EXPERIMENTS.md §Perf iteration 3);
  the paper's ST/CSR kernels remain the subjects of the Fig. 8-10
  benches.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import batched_spmm_ell, batched_spmm_st


# --------------------------------------------------------------------------
# Configs (paper §V-B, Table I and the architecture description)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GcnConfig:
    """ChemGCN architecture + padded-batch geometry."""

    name: str
    max_nodes: int          # M: padded node count (Table I "Max dim" = 50)
    feat_dim: int           # F0: input feature width
    channels: int           # bond-type channels (adjacency per channel)
    hidden: tuple           # conv layer widths
    n_out: int              # tasks (tox21: 12) or classes (reaction100: 100)
    loss: str               # "bce" (multi-task) | "softmax"
    nnz_cap: int            # padded non-zeros per (sample, channel)
    ell_width: int          # R: max non-zeros per row (ELL packing)
    train_batch: int        # Table I "Batch size"
    infer_batch: int        # §V-B: inference batch = 200


# Tox21: 7,862 molecules, max dim 50, 2 conv layers of width 64, 12 tasks.
TOX21 = GcnConfig(
    name="tox21", max_nodes=50, feat_dim=16, channels=4, hidden=(64, 64),
    n_out=12, loss="bce", nnz_cap=128, ell_width=12, train_batch=50,
    infer_batch=200,
)

# Reaction100: 75,477 graphs, 3 conv layers of width 512, 100 classes.
REACTION100 = GcnConfig(
    name="reaction100", max_nodes=50, feat_dim=16, channels=4,
    hidden=(512, 512, 512), n_out=100, loss="softmax", nnz_cap=128,
    ell_width=12, train_batch=100, infer_batch=200,
)

CONFIGS = {c.name: c for c in (TOX21, REACTION100)}


# --------------------------------------------------------------------------
# Parameters: flat, deterministically-ordered list (the artifact ABI)
# --------------------------------------------------------------------------


def param_specs(cfg: GcnConfig) -> list:
    """[(name, shape)] in the order the AOT artifacts take/return them."""
    specs = []
    fin = cfg.feat_dim
    for i, fout in enumerate(cfg.hidden):
        specs.append((f"conv{i}.w", (cfg.channels, fin, fout)))
        specs.append((f"conv{i}.b", (cfg.channels, fout)))
        specs.append((f"conv{i}.gamma", (fout,)))
        specs.append((f"conv{i}.beta", (fout,)))
        fin = fout
    specs.append(("readout.w", (cfg.hidden[-1], cfg.n_out)))
    specs.append(("readout.b", (cfg.n_out,)))
    return specs


def init_params(cfg: GcnConfig, seed: int = 0) -> list:
    """Glorot-ish init; gamma=1, beta=0, biases=0."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".w"):
            fan_in = shape[-2]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
        elif name.endswith(".gamma"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


# --------------------------------------------------------------------------
# SpMM op with Batched-SpMM backward (custom VJP)
# --------------------------------------------------------------------------


@jax.custom_vjp
def spmm_st_op(ids: jax.Array, vals: jax.Array, dense: jax.Array) -> jax.Array:
    """C = A @ B through the L1 Pallas ST kernel; ids/vals are data."""
    return batched_spmm_st(ids, vals, dense)


def _spmm_fwd(ids, vals, dense):
    return batched_spmm_st(ids, vals, dense), (ids, vals)


def _spmm_bwd(res, g):
    ids, vals = res
    # dB = A^T dC: for SparseTensor, transposing is swapping id columns —
    # the backward pass is itself a Batched SpMM (paper §IV-D).
    ids_t = ids[:, :, ::-1]
    d_dense = batched_spmm_st(ids_t, vals, g)
    return (
        jnp.zeros(ids.shape, dtype=jax.dtypes.float0),
        jnp.zeros_like(vals),
        d_dense,
    )


spmm_st_op.defvjp(_spmm_fwd, _spmm_bwd)


@jax.custom_vjp
def spmm_ell_op(cols: jax.Array, vals: jax.Array, dense: jax.Array) -> jax.Array:
    """C = A @ B through the gather-only ELL kernel (the model hot
    path; §Perf iteration 3). cols/vals are data."""
    return batched_spmm_ell(cols, vals, dense)


def _spmm_ell_fwd(cols, vals, dense):
    return batched_spmm_ell(cols, vals, dense), (cols, vals)


def _spmm_ell_bwd(res, g):
    cols, vals = res
    # dB = A^T dC. Molecular adjacency (undirected bonds + self loops)
    # is SYMMETRIC, so A^T = A and the same ELL arrays serve the
    # backward pass — still a single gather-only Batched SpMM ("The
    # Batched SpMM is also applied to backward propagation", §IV-D).
    # Directed graphs would pack A^T alongside A at batch-build time.
    d_dense = batched_spmm_ell(cols, vals, g)
    return (
        jnp.zeros(cols.shape, dtype=jax.dtypes.float0),
        jnp.zeros_like(vals),
        d_dense,
    )


spmm_ell_op.defvjp(_spmm_ell_fwd, _spmm_ell_bwd)


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------


def graph_norm(h, mask, gamma, beta, eps=1e-5):
    """Per-graph masked normalization: per (sample, feature) stats over
    that sample's real nodes, then affine, then re-mask (padded node rows
    stay exactly zero so downstream SpMM/readout never see them)."""
    w = mask[..., None]                                    # [B, M, 1]
    cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
    mean = jnp.sum(h * w, axis=1, keepdims=True) / cnt     # [B, 1, F]
    var = jnp.sum(((h - mean) ** 2) * w, axis=1, keepdims=True) / cnt
    hn = (h - mean) * jax.lax.rsqrt(var + eps)
    return (gamma * hn + beta) * w


def forward(cfg: GcnConfig, params: list, ell_cols, ell_vals, x, mask):
    """ChemGCN forward.

    Args:
      params: flat list per param_specs(cfg).
      ell_cols: [B, CH, M, R] int32 ELL columns per channel.
      ell_vals: [B, CH, M, R] f32 ELL values (0 = padding slot).
      x:    [B, M, F0] node features (padded rows zero).
      mask: [B, M] f32 node validity.

    Returns logits [B, n_out].
    """
    p = iter(params)
    h = x
    for _ in cfg.hidden:
        w, b, gamma, beta = next(p), next(p), next(p), next(p)
        # Fig. 7: one MatMul / Add / BatchedSpMM per *channel* —
        # O(channel) device ops for the whole minibatch.
        y = None
        for ch in range(cfg.channels):
            u = jnp.einsum("bmf,fo->bmo", h, w[ch])        # MatMul
            u = u + b[ch]                                  # Add (bias)
            c = spmm_ell_op(ell_cols[:, ch], ell_vals[:, ch], u)  # BatchedSpMM
            y = c if y is None else y + c                  # ElementWiseAdd
        h = jax.nn.relu(graph_norm(y, mask, gamma, beta))
    w_out, b_out = next(p), next(p)
    pooled = jnp.sum(h, axis=1)                            # sum readout
    return pooled @ w_out + b_out


def loss_fn(cfg: GcnConfig, params, ell_cols, ell_vals, x, mask, labels):
    """Mean loss over the batch — exactly (1/B) * sum of per-sample
    losses, so non-batched per-sample grads average to the batched grad."""
    logits = forward(cfg, params, ell_cols, ell_vals, x, mask)
    if cfg.loss == "bce":
        # Multi-task binary cross-entropy with logits (labels [B, n_out]).
        z = jax.nn.log_sigmoid(logits)
        zc = jax.nn.log_sigmoid(-logits)
        per = -(labels * z + (1.0 - labels) * zc)
        return jnp.mean(jnp.sum(per, axis=-1))
    elif cfg.loss == "softmax":
        # One-hot labels [B, n_out].
        logp = jax.nn.log_softmax(logits, axis=-1)
        return jnp.mean(-jnp.sum(labels * logp, axis=-1))
    raise ValueError(f"unknown loss {cfg.loss}")


# --------------------------------------------------------------------------
# Training steps (both dispatch modes)
# --------------------------------------------------------------------------


def train_step(cfg: GcnConfig, params, ell_cols, ell_vals, x, mask, labels, lr):
    """Batched mode: fwd + bwd + SGD in one executable.

    lr arrives as shape-[1] f32 (rank-0 literals are awkward across the
    PJRT text boundary). Returns (*new_params, loss[1])."""
    lr_s = lr[0]
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, ell_cols, ell_vals, x, mask, labels)
    )(params)
    new_params = [p - lr_s * g for p, g in zip(params, grads)]
    return tuple(new_params) + (jnp.reshape(loss, (1,)),)


def grad_sample(cfg: GcnConfig, params, ell_cols, ell_vals, x, mask, labels):
    """Non-batched mode: gradient of ONE sample's loss (inputs carry a
    leading batch axis of 1). The rust coordinator sums these across the
    minibatch and calls apply_sgd. Returns (*grads, loss[1])."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, ell_cols, ell_vals, x, mask, labels)
    )(params)
    return tuple(grads) + (jnp.reshape(loss, (1,)),)


def apply_sgd(params, grad_sums, scale):
    """params <- params - scale * grad_sums  (scale = lr / batch, shape [1]).

    Separate tiny executable so the non-batched path never needs python."""
    s = scale[0]
    return tuple(p - s * g for p, g in zip(params, grad_sums))
