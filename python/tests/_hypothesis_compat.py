"""Deterministic stand-in for `hypothesis` (gate, don't install).

The offline image carries no `hypothesis`, which used to fail the whole
suite at *collection* time. This shim re-exports the real library when
it is installed; otherwise it provides the tiny subset the suite uses
(`given`, `settings`, `strategies.integers`, `strategies.sampled_from`)
backed by a seeded random sweep, so the property tests still execute a
meaningful number of deterministic examples.
"""

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies
except ModuleNotFoundError:
    import random

    _DEFAULT_MAX_EXAMPLES = 15

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature,
            # not the property's drawn parameters (it would treat them
            # as fixtures).
            def wrapper():
                # `@settings` sits above `@given`, so it annotates this
                # wrapper; read the budget at call time.
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(0xB5F3 ^ len(fn.__qualname__))
                for _ in range(n):
                    drawn = [s.draw(rng) for s in arg_strategies]
                    drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*drawn, **drawn_kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


st = strategies
