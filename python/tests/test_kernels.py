"""L1 kernel correctness: Pallas batched SpMM vs pure-jnp oracles.

Hypothesis sweeps shapes, sparsity, padding amounts, and block sizes;
every property here is a behaviour the rust runtime relies on (the AOT
artifacts embed these kernels verbatim).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from compile.kernels import batched_spmm_csr, batched_spmm_st, ref

RTOL = 1e-5
ATOL = 1e-5


def make_st_batch(rng, b, m, nnz, pad_frac):
    """Random PaddedSparseTensor batch with ~pad_frac of slots padded."""
    ids = rng.integers(0, m, size=(b, nnz, 2)).astype(np.int32)
    vals = rng.normal(size=(b, nnz)).astype(np.float32)
    n_pad = int(nnz * pad_frac)
    if n_pad:
        ids[:, nnz - n_pad:, :] = 0
        vals[:, nnz - n_pad:] = 0.0
    return ids, vals


def make_csr_batch(rng, b, m, nnz_cap):
    """Random PaddedCSR batch: per-matrix random row counts and nnz."""
    rpt = np.zeros((b, m + 1), np.int32)
    colids = np.zeros((b, nnz_cap), np.int32)
    vals = np.zeros((b, nnz_cap), np.float32)
    for i in range(b):
        true_m = int(rng.integers(1, m + 1))
        counts = rng.integers(0, 4, size=m)
        counts[true_m:] = 0
        cum = np.minimum(np.concatenate([[0], np.cumsum(counts)]), nnz_cap)
        rpt[i] = cum
        k = int(cum[-1])
        colids[i, :k] = rng.integers(0, m, size=k)
        vals[i, :k] = rng.normal(size=k)
    return rpt, colids, vals


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    m=st.sampled_from([4, 8, 16, 32]),
    n=st.sampled_from([8, 16, 32, 64]),
    nnz_per_row=st.integers(1, 5),
    pad_frac=st.sampled_from([0.0, 0.25, 0.5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_st_matches_oracle(b, m, n, nnz_per_row, pad_frac, seed):
    rng = np.random.default_rng(seed)
    nnz = max(1, m * nnz_per_row)
    ids, vals = make_st_batch(rng, b, m, nnz, pad_frac)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    out = batched_spmm_st(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense))
    expect = ref.spmm_st_ref(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    m=st.sampled_from([4, 8, 16, 32]),
    n=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_csr_matches_oracle(b, m, n, seed):
    rng = np.random.default_rng(seed)
    rpt, colids, vals = make_csr_batch(rng, b, m, nnz_cap=4 * m)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    out = batched_spmm_csr(
        jnp.asarray(rpt), jnp.asarray(colids), jnp.asarray(vals), jnp.asarray(dense)
    )
    expect = ref.spmm_csr_ref(
        jnp.asarray(rpt), jnp.asarray(colids), jnp.asarray(vals), jnp.asarray(dense)
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    block_n=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_st_column_blocking_invariant(block_n, seed):
    """Cache blocking (Fig. 5-b) must not change results: any block_n
    dividing n produces the same output."""
    rng = np.random.default_rng(seed)
    b, m, n, nnz = 3, 16, 64, 32
    ids, vals = make_st_batch(rng, b, m, nnz, 0.25)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    full = batched_spmm_st(
        jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense), block_n=n
    )
    blocked = batched_spmm_st(
        jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense), block_n=block_n
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    block_n=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_csr_column_blocking_invariant(block_n, seed):
    """Fig. 5-(d): CSR blocking along columns is semantics-preserving."""
    rng = np.random.default_rng(seed)
    b, m, n = 3, 16, 64
    rpt, colids, vals = make_csr_batch(rng, b, m, nnz_cap=3 * m)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    full = batched_spmm_csr(
        jnp.asarray(rpt), jnp.asarray(colids), jnp.asarray(vals), jnp.asarray(dense),
        block_n=n,
    )
    blocked = batched_spmm_csr(
        jnp.asarray(rpt), jnp.asarray(colids), jnp.asarray(vals), jnp.asarray(dense),
        block_n=block_n,
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked), rtol=RTOL, atol=ATOL)


def test_st_duplicate_entries_accumulate():
    """Fig. 2/3 semantics: duplicate (row, col) non-zeros add up — the
    behaviour the atomic add provides on the GPU."""
    ids = np.array([[[1, 2], [1, 2], [0, 0]]], np.int32)
    vals = np.array([[2.0, 3.0, 1.0]], np.float32)
    dense = np.eye(4, dtype=np.float32)[None]
    out = np.asarray(
        batched_spmm_st(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense))
    )
    assert out[0, 1, 2] == pytest.approx(5.0)
    assert out[0, 0, 0] == pytest.approx(1.0)


def test_st_padding_is_identity():
    """Padding slots (val=0 at (0,0)) must contribute nothing."""
    rng = np.random.default_rng(7)
    b, m, n, nnz = 2, 8, 16, 10
    ids, vals = make_st_batch(rng, b, m, nnz, 0.0)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    base = np.asarray(
        batched_spmm_st(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense))
    )
    ids_pad = np.concatenate([ids, np.zeros((b, 6, 2), np.int32)], axis=1)
    vals_pad = np.concatenate([vals, np.zeros((b, 6), np.float32)], axis=1)
    padded = np.asarray(
        batched_spmm_st(jnp.asarray(ids_pad), jnp.asarray(vals_pad), jnp.asarray(dense))
    )
    np.testing.assert_allclose(base, padded, rtol=RTOL, atol=ATOL)


def test_csr_empty_rows_and_matrices():
    """Empty rows (rpt[r] == rpt[r+1]) and fully-empty matrices produce
    zero rows — the 'threads terminate immediately' case."""
    rpt = np.array([[0, 0, 2, 2, 3], [0, 0, 0, 0, 0]], np.int32)
    colids = np.array([[1, 3, 0, 0], [0, 0, 0, 0]], np.int32)
    vals = np.array([[1.0, 2.0, 4.0, 9.9], [9.9, 9.9, 9.9, 9.9]], np.float32)
    dense = np.tile(np.eye(4, dtype=np.float32)[None], (2, 1, 1))
    out = np.asarray(
        batched_spmm_csr(
            jnp.asarray(rpt), jnp.asarray(colids), jnp.asarray(vals), jnp.asarray(dense)
        )
    )
    expect0 = np.zeros((4, 4), np.float32)
    expect0[1, 1] = 1.0
    expect0[1, 3] = 2.0
    expect0[3, 0] = 4.0
    np.testing.assert_allclose(out[0], expect0, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(out[1], np.zeros((4, 4)), rtol=RTOL, atol=ATOL)


def test_st_csr_agree_on_same_matrix():
    """The two formats encode the same matrix -> same product."""
    rng = np.random.default_rng(11)
    b, m, n = 2, 12, 32
    rpt, colids, vals = make_csr_batch(rng, b, m, nnz_cap=3 * m)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    csr_out = np.asarray(
        batched_spmm_csr(
            jnp.asarray(rpt), jnp.asarray(colids), jnp.asarray(vals), jnp.asarray(dense)
        )
    )
    # convert CSR -> ST
    nnz_cap = colids.shape[1]
    ids = np.zeros((b, nnz_cap, 2), np.int32)
    st_vals = np.zeros((b, nnz_cap), np.float32)
    for i in range(b):
        k = rpt[i, -1]
        rows = np.asarray(ref.csr_row_of_slot(jnp.asarray(rpt[i]), nnz_cap))[:k]
        ids[i, :k, 0] = rows
        ids[i, :k, 1] = colids[i, :k]
        st_vals[i, :k] = vals[i, :k]
    st_out = np.asarray(
        batched_spmm_st(jnp.asarray(ids), jnp.asarray(st_vals), jnp.asarray(dense))
    )
    np.testing.assert_allclose(csr_out, st_out, rtol=RTOL, atol=ATOL)


def test_dense_baseline_agrees():
    """The batched-GEMM baseline on the densified matrix equals SpMM."""
    rng = np.random.default_rng(13)
    b, m, n, nnz = 2, 8, 16, 20
    ids, vals = make_st_batch(rng, b, m, nnz, 0.25)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    a_dense = ref.st_to_dense(jnp.asarray(ids), jnp.asarray(vals), m, m)
    gemm = np.asarray(ref.spmm_dense_ref(a_dense, jnp.asarray(dense)))
    spmm = np.asarray(
        batched_spmm_st(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense))
    )
    np.testing.assert_allclose(gemm, spmm, rtol=1e-4, atol=1e-4)


# ---- ELL (gather-only) kernel ------------------------------------------------

from compile.kernels import batched_spmm_ell


def make_ell_batch(rng, b, m, r, fill_frac=0.7):
    """Random ELL batch: each row gets a random number of real slots."""
    cols = rng.integers(0, m, size=(b, m, r)).astype(np.int32)
    vals = rng.normal(size=(b, m, r)).astype(np.float32)
    keep = rng.uniform(size=(b, m, r)) < fill_frac
    vals = np.where(keep, vals, 0.0).astype(np.float32)
    cols = np.where(keep, cols, 0).astype(np.int32)
    return cols, vals


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    m=st.sampled_from([4, 8, 16, 32]),
    r=st.integers(1, 8),
    n=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ell_matches_oracle(b, m, r, n, seed):
    rng = np.random.default_rng(seed)
    cols, vals = make_ell_batch(rng, b, m, r)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    out = batched_spmm_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(dense))
    expect = ref.spmm_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(dense))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=RTOL, atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(block_n=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
def test_ell_column_blocking_invariant(block_n, seed):
    rng = np.random.default_rng(seed)
    b, m, r, n = 3, 16, 5, 64
    cols, vals = make_ell_batch(rng, b, m, r)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    full = batched_spmm_ell(
        jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(dense), block_n=n
    )
    blocked = batched_spmm_ell(
        jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(dense), block_n=block_n
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked), rtol=RTOL, atol=ATOL)


def test_ell_agrees_with_st_on_same_matrix():
    """ELL and ST encode the same matrix -> same product (the contract
    that lets the model switch formats)."""
    rng = np.random.default_rng(17)
    b, m, n, nnz = 2, 12, 32, 24
    ids, vals = make_st_batch(rng, b, m, nnz, 0.25)
    dense = rng.normal(size=(b, m, n)).astype(np.float32)
    st_out = np.asarray(
        batched_spmm_st(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(dense))
    )
    r = 12
    cols_b = np.zeros((b, m, r), np.int32)
    vals_b = np.zeros((b, m, r), np.float32)
    for bi in range(b):
        c, v = ref.st_to_ell(ids[bi], vals[bi], m, r)
        cols_b[bi], vals_b[bi] = c, v
    ell_out = np.asarray(
        batched_spmm_ell(jnp.asarray(cols_b), jnp.asarray(vals_b), jnp.asarray(dense))
    )
    np.testing.assert_allclose(st_out, ell_out, rtol=1e-4, atol=1e-4)
