"""AOT pipeline tests: sweep-table consistency and output-shape
inference (no lowering here — the heavy path is covered by `make
artifacts` + the rust integration tests)."""

import jax
import numpy as np

from compile import aot, model as M
from compile.kernels import blocking


def test_sweeps_reference_paper_parameters():
    # Table I geometry: the GCN-application proxies use dim 50.
    assert aot.SWEEPS["fig8a"]["dim"] == 50
    assert aot.SWEEPS["fig8a"]["batch"] == 50
    assert aot.SWEEPS["fig8b"]["batch"] == 100
    # Fig. 9 panels
    assert [aot.SWEEPS[k]["dim"] for k in ("fig9a", "fig9b", "fig9c")] == [32, 64, 128]
    assert aot.SWEEPS["fig9d"]["batch"] == 50
    assert aot.SWEEPS["fig9e"]["z"] == 1
    assert aot.SWEEPS["fig9f"]["z"] == 5
    # Fig. 10 mixed ranges
    assert aot.SWEEPS["fig10"]["mixed"] is True
    assert aot.SWEEPS["fig10"]["dim_range"] == [32, 256]
    assert aot.SWEEPS["fig10"]["z_range"] == [1, 5]


def test_model_io_specs_match_config():
    cfg = M.TOX21
    io = aot.model_io_specs(cfg, 7, with_labels=True)
    names = [n for n, _, _ in io]
    assert names == ["ell_cols", "ell_vals", "x", "mask", "labels"]
    shapes = {n: s for n, s, _ in io}
    assert shapes["ell_cols"] == (7, cfg.channels, cfg.max_nodes, cfg.ell_width)
    assert shapes["labels"] == (7, cfg.n_out)


def test_spmm_fn_output_shapes():
    fn = aot.st_fn()
    out = jax.eval_shape(
        fn,
        jax.ShapeDtypeStruct((3, 10, 2), np.int32),
        jax.ShapeDtypeStruct((3, 10), np.float32),
        jax.ShapeDtypeStruct((3, 8, 16), np.float32),
    )
    assert out[0].shape == (3, 8, 16)
    fn = aot.csr_fn()
    out = jax.eval_shape(
        fn,
        jax.ShapeDtypeStruct((3, 9), np.int32),
        jax.ShapeDtypeStruct((3, 10), np.int32),
        jax.ShapeDtypeStruct((3, 10), np.float32),
        jax.ShapeDtypeStruct((3, 8, 16), np.float32),
    )
    assert out[0].shape == (3, 8, 16)


def test_configs_respect_artifact_nnz_budget():
    """The molecule generator guarantees per-channel nnz <= nnz_cap via
    MoleculeSpec.max_bonds_per_channel (rust side); the python configs
    must agree: 2 * max_bonds + max_nodes <= nnz_cap."""
    for cfg in M.CONFIGS.values():
        max_bonds = (cfg.nnz_cap - cfg.max_nodes) // 2
        assert max_bonds >= 30, f"{cfg.name}: budget too tight"
        assert blocking.plan_blocks(cfg.max_nodes, cfg.hidden[0]).staged


def test_sweep_nb_divisible_by_default_blocks():
    """Every sweep n_B must be compatible with the Fig. 5 planner's
    block size (the artifact lowering asserts divisibility)."""
    for key, sw in aot.SWEEPS.items():
        for nb in sw["nbs"]:
            plan = blocking.plan_blocks(sw["dim"], nb)
            bn = plan.block_n if plan.staged else nb
            assert nb % bn == 0, f"{key}: n_B={nb} block={bn}"
