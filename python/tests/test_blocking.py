"""Planner tests (paper §IV-A/B/C policies).

The golden values here are mirrored by rust unit tests in
``rust/src/simulator/cost.rs`` — both sides implement the same subWarp
and cache-blocking formulas, and these tests pin the contract.
"""

import pytest
from _hypothesis_compat import given, strategies as st

from compile.kernels import blocking


# ---- subWarp policy (§IV-A) -------------------------------------------------

@pytest.mark.parametrize(
    "n_b,expect",
    [
        (1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (8, 8), (9, 16),
        (16, 16),           # n_B <= 16: min 2^p >= n_B
        (17, 32), (32, 32), (64, 32), (512, 32),  # n_B > 16: capped at warp
    ],
)
def test_subwarp_golden(n_b, expect):
    assert blocking.subwarp(n_b) == expect


@given(st.integers(1, 4096))
def test_subwarp_is_pow2_and_capped(n_b):
    sw = blocking.subwarp(n_b)
    assert sw & (sw - 1) == 0
    assert 1 <= sw <= 32
    if n_b <= 16:
        assert sw >= n_b and sw // 2 < n_b


# ---- cache blocking (§IV-B/C) ----------------------------------------------

def test_case1_whole_output_fits():
    # 50 x 64 f32 = 12.5 KB <= 32 KB -> single block (Fig. 5-a)
    plan = blocking.plan_blocks(50, 64)
    assert plan.staged and plan.n_blocks == 1 and plan.block_n == 64


def test_case2_column_split():
    # 50 x 512 f32 = 100 KB > 32 KB -> split columns (Fig. 5-b)
    plan = blocking.plan_blocks(50, 512)
    assert plan.staged and plan.n_blocks > 1
    assert plan.m * plan.block_n * 4 <= blocking.DEFAULT_SMEM_BUDGET_BYTES


def test_case3_threshold_matches_paper():
    """Paper §IV-C: with a 32 KB budget 'only the input sparse matrices
    with m_A > 8192 require the case 3'."""
    # m = 8192, narrowest useful block (min_block_n=1) is 8192*4 = 32KB: stages.
    assert blocking.plan_blocks(8192, 512, min_block_n=1).staged
    assert not blocking.plan_blocks(8193, 512, min_block_n=1).staged


@given(
    m=st.integers(1, 4096),
    n_b=st.sampled_from([8, 16, 32, 64, 128, 256, 512, 1024]),
)
def test_plan_covers_all_columns(m, n_b):
    plan = blocking.plan_blocks(m, n_b)
    assert plan.n_blocks * plan.block_n >= plan.n_b
    if plan.staged and plan.n_blocks > 1:
        assert plan.bytes_per_block <= blocking.DEFAULT_SMEM_BUDGET_BYTES


def test_batch_plan_uses_max_m():
    """§IV-C: blocking is decided by max m_A in the batch and applied to
    every operation in the batch."""
    small_only = blocking.plan_batch([16, 32, 50], 512)
    with_big = blocking.plan_batch([16, 32, 50, 300], 512)
    assert small_only.n_blocks <= with_big.n_blocks
    assert with_big.m == 300


def test_next_pow2_rejects_nonpositive():
    with pytest.raises(ValueError):
        blocking.next_pow2(0)
    with pytest.raises(ValueError):
        blocking.plan_blocks(0, 8)
