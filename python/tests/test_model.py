"""L2 model tests: shapes, gradient flow through the Pallas custom-VJP,
per-sample decomposability (the non-batched dispatch contract), and
kernel-variant equivalence inside the model."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def tiny_cfg(loss="softmax", n_out=3):
    return M.GcnConfig(
        name="t", max_nodes=8, feat_dim=4, channels=2, hidden=(8, 8),
        n_out=n_out, loss=loss, nnz_cap=16, ell_width=6, train_batch=4,
        infer_batch=4,
    )


def symmetric_ell(rng, b, ch, m, r, n_edges=6):
    """Random SYMMETRIC adjacency (undirected edges + self loops) in ELL
    form — the structure the model's custom VJP assumes (A^T == A)."""
    cols = np.zeros((b, ch, m, r), np.int32)
    vals = np.zeros((b, ch, m, r), np.float32)
    fill = np.zeros((b, ch, m), np.int64)

    def put(bi, ci, u, v, w):
        s = fill[bi, ci, u]
        if s < r:
            cols[bi, ci, u, s] = v
            vals[bi, ci, u, s] = w
            fill[bi, ci, u] += 1

    for bi in range(b):
        for ci in range(ch):
            for u in range(m):
                put(bi, ci, u, u, 1.0)  # self loop
            for _ in range(n_edges):
                u, v = rng.integers(0, m, size=2)
                if u == v:
                    continue
                w = float(rng.uniform(0.5, 1.0))
                put(bi, ci, u, v, w)
                put(bi, ci, v, u, w)
    return cols, vals


def make_batch(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    m, ch, r = cfg.max_nodes, cfg.channels, cfg.ell_width
    cols, vals = symmetric_ell(rng, b, ch, m, r)
    x = rng.normal(size=(b, m, cfg.feat_dim)).astype(np.float32)
    mask = np.ones((b, m), np.float32)
    mask[:, m - 2:] = 0
    x[:, m - 2:, :] = 0
    if cfg.loss == "softmax":
        labels = np.eye(cfg.n_out, dtype=np.float32)[
            rng.integers(0, cfg.n_out, size=b)
        ]
    else:
        labels = (rng.uniform(size=(b, cfg.n_out)) > 0.5).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (cols, vals, x, mask, labels))


def test_param_specs_layout():
    cfg = tiny_cfg()
    specs = M.param_specs(cfg)
    names = [n for n, _ in specs]
    assert names == [
        "conv0.w", "conv0.b", "conv0.gamma", "conv0.beta",
        "conv1.w", "conv1.b", "conv1.gamma", "conv1.beta",
        "readout.w", "readout.b",
    ]
    assert specs[0][1] == (2, 4, 8)
    assert specs[-2][1] == (8, 3)


def test_init_params_deterministic_and_shaped():
    cfg = tiny_cfg()
    a = M.init_params(cfg, seed=1)
    b = M.init_params(cfg, seed=1)
    c = M.init_params(cfg, seed=2)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(
        not np.array_equal(np.asarray(pa), np.asarray(pc)) for pa, pc in zip(a, c)
    )
    for (name, shape), p in zip(M.param_specs(cfg), a):
        assert p.shape == shape, name


def test_forward_shape_and_mask_invariance():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    cols, vals, x, mask, _ = make_batch(cfg, 4)
    logits = M.forward(cfg, params, cols, vals, x, mask)
    assert logits.shape == (4, 3)
    # Changing padded-node features must not change logits (they are
    # masked out before every op that could observe them).
    x2 = x.at[:, cfg.max_nodes - 1, :].set(99.0) * mask[..., None]
    logits2 = M.forward(cfg, params, cols, vals, x2, mask)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2), rtol=1e-6)


def test_grad_flows_through_spmm_custom_vjp():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    batch = make_batch(cfg, 4)
    loss, grads = jax.value_and_grad(
        lambda ps: M.loss_fn(cfg, ps, *batch)
    )(params)
    assert np.isfinite(float(loss))
    # Every parameter must receive some gradient signal.
    for (name, _), g in zip(M.param_specs(cfg), grads):
        norm = float(jnp.abs(g).sum())
        assert np.isfinite(norm), name
        assert norm > 0.0, f"zero grad for {name}"


def test_custom_vjp_matches_reference_grad():
    """Grad through the Pallas batched-SpMM custom VJP must equal grad
    through the pure-jnp scatter-add oracle."""
    from compile.kernels import ref

    rng = np.random.default_rng(3)
    b, m, nnz, n = 2, 6, 10, 8
    ids = jnp.asarray(rng.integers(0, m, size=(b, nnz, 2)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(b, nnz)).astype(np.float32))
    dense = jnp.asarray(rng.normal(size=(b, m, n)).astype(np.float32))

    def via_kernel(d):
        return jnp.sum(M.spmm_st_op(ids, vals, d) ** 2)

    def via_ref(d):
        return jnp.sum(ref.spmm_st_ref(ids, vals, d) ** 2)

    g_kernel = jax.grad(via_kernel)(dense)
    g_ref = jax.grad(via_ref)(dense)
    np.testing.assert_allclose(
        np.asarray(g_kernel), np.asarray(g_ref), rtol=1e-4, atol=1e-4
    )


def test_ell_custom_vjp_matches_reference_grad_symmetric():
    """For symmetric A (the molecular case) the ELL custom VJP must
    equal autodiff through the pure-jnp ELL oracle."""
    from compile.kernels import ref

    rng = np.random.default_rng(4)
    cols_np, vals_np = symmetric_ell(rng, 2, 1, 6, 5, n_edges=4)
    cols = jnp.asarray(cols_np[:, 0])
    vals = jnp.asarray(vals_np[:, 0])
    dense = jnp.asarray(rng.normal(size=(2, 6, 8)).astype(np.float32))

    def via_kernel(d):
        return jnp.sum(jnp.sin(M.spmm_ell_op(cols, vals, d)))

    def via_ref(d):
        return jnp.sum(jnp.sin(ref.spmm_ell_ref(cols, vals, d)))

    g_kernel = jax.grad(via_kernel)(dense)
    g_ref = jax.grad(via_ref)(dense)
    np.testing.assert_allclose(
        np.asarray(g_kernel), np.asarray(g_ref), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("loss", ["softmax", "bce"])
def test_per_sample_decomposability(loss):
    """sum of grad_sample == B * grad(mean loss): the exact contract the
    non-batched dispatch mode (Table II) relies on."""
    cfg = tiny_cfg(loss=loss)
    params = M.init_params(cfg)
    batch = make_batch(cfg, 4, seed=7)
    loss_b, grads_b = jax.value_and_grad(
        lambda ps: M.loss_fn(cfg, ps, *batch)
    )(params)
    total = None
    loss_sum = 0.0
    for i in range(4):
        one = tuple(a[i : i + 1] for a in batch)
        outs = M.grad_sample(cfg, params, *one)
        g, l = outs[:-1], outs[-1]
        loss_sum += float(l[0])
        total = list(g) if total is None else [a + b for a, b in zip(total, g)]
    np.testing.assert_allclose(float(loss_b), loss_sum / 4, rtol=1e-5)
    for gb, gs in zip(grads_b, total):
        np.testing.assert_allclose(
            np.asarray(gb), np.asarray(gs) / 4, rtol=3e-4, atol=3e-5
        )


def test_train_step_reduces_loss():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    batch = make_batch(cfg, 4, seed=9)
    lr = jnp.asarray([0.1], jnp.float32)
    losses = []
    for _ in range(10):
        out = M.train_step(cfg, params, *batch, lr)
        params = list(out[:-1])
        losses.append(float(out[-1][0]))
    assert losses[-1] < losses[0], losses


def test_apply_sgd_matches_manual():
    cfg = tiny_cfg()
    params = M.init_params(cfg)
    grads = [jnp.ones_like(p) for p in params]
    out = M.apply_sgd(params, grads, jnp.asarray([0.5], jnp.float32))
    for p, q in zip(params, out):
        np.testing.assert_allclose(np.asarray(q), np.asarray(p) - 0.5, rtol=1e-6)


def test_graph_norm_masked_stats():
    h = jnp.asarray(np.random.default_rng(0).normal(size=(2, 5, 3)).astype(np.float32))
    mask = jnp.asarray(np.array([[1, 1, 1, 0, 0], [1, 1, 1, 1, 1]], np.float32))
    gamma = jnp.ones(3)
    beta = jnp.zeros(3)
    out = M.graph_norm(h, mask, gamma, beta)
    # padded rows exactly zero
    np.testing.assert_array_equal(np.asarray(out[0, 3:]), 0.0)
    # masked mean ~ 0, masked var ~ 1 per (sample, feature)
    valid = np.asarray(out[0, :3])
    assert abs(valid.mean()) < 0.2
